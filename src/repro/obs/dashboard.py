"""Offline HTML dashboard: one self-contained file, zero dependencies.

``render_dashboard(report, path)`` turns a unified ``repro.profiler``
Report — local session or fleet aggregate, live or replayed from a
spool capture — into a single HTML document with inline SVG:

  * per-file bandwidth timeline heatmap (top files by bytes moved),
  * per-rank bandwidth timeline heatmap (one row in local mode),
  * the Darshan access-size histogram (read + write, the 10 bins of
    ``repro.core.counters.SIZE_BIN_BOUNDS``),
  * insight findings as timeline markers plus a detail table,
  * the tune-action audit trail overlaid on the same timeline,
  * the self-telemetry health panel and raw metrics table (repro.obs).

Everything renders from ``report.segments_table()`` (the columnar
``SegmentColumns`` batch) with numpy binning — no per-segment Python
loop — and the document references no external asset, so the file can
be archived next to a spool capture and opened years later.

The section ids (``per-file-heatmap``, ``per-rank-heatmap``,
``size-hist``, ``findings``, ``tune-audit``, ``health-panel``,
``metrics``) are a stable contract: tests golden-match them, and
tooling may deep-link ``dashboard.html#findings``.
"""
from __future__ import annotations

import html
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.counters import SIZE_BIN_NAMES

TIME_BINS = 60
MAX_FILE_ROWS = 16

_CELL_W, _CELL_H = 13, 18
_LABEL_W = 240

# two-stop heat ramp: quiet bins stay dark, hot bins go amber
_COLD = (24, 32, 74)
_MID = (54, 92, 141)
_HOT = (247, 183, 51)


def _heat_color(frac: float) -> str:
    frac = min(max(frac, 0.0), 1.0)
    if frac <= 0.5:
        a, b, t = _COLD, _MID, frac * 2
    else:
        a, b, t = _MID, _HOT, (frac - 0.5) * 2
    return "#%02x%02x%02x" % tuple(
        int(round(a[i] + (b[i] - a[i]) * t)) for i in range(3))


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _bin_rows(cols, row_of: np.ndarray, nrows: int,
              window: Tuple[float, float]) -> np.ndarray:
    """(nrows, TIME_BINS) byte totals: segment ``length`` summed into
    its row's time bin (vectorized ``np.add.at`` scatter)."""
    mat = np.zeros((nrows, TIME_BINS), dtype=np.float64)
    if len(cols) == 0 or nrows == 0:
        return mat
    t0, t1 = window
    span = max(t1 - t0, 1e-9)
    bins = ((np.asarray(cols.start, dtype=np.float64) - t0)
            / span * TIME_BINS).astype(np.int64)
    np.clip(bins, 0, TIME_BINS - 1, out=bins)
    np.add.at(mat, (row_of, bins),
              np.asarray(cols.length, dtype=np.float64))
    return mat


def _heatmap_svg(section_id: str, labels: Sequence[str],
                 mat: np.ndarray, window: Tuple[float, float],
                 markers: Sequence[Tuple[float, str, str]] = ()) -> str:
    """One heatmap: a row per label, a column per time bin, optional
    vertical markers (``(time_s, css_class, tooltip)`` — findings and
    tune actions land on the shared timeline)."""
    nrows = len(labels)
    t0, t1 = window
    span = max(t1 - t0, 1e-9)
    w = _LABEL_W + TIME_BINS * _CELL_W + 10
    h = nrows * _CELL_H + 34
    peak = float(mat.max()) if mat.size else 0.0
    out = [f'<svg id="{section_id}" width="{w}" height="{h}" '
           f'xmlns="http://www.w3.org/2000/svg" font-family="monospace" '
           f'font-size="11">']
    for r, label in enumerate(labels):
        y = r * _CELL_H
        out.append(f'<text x="{_LABEL_W - 6}" y="{y + 13}" '
                   f'text-anchor="end">{html.escape(label)}</text>')
        for b in range(TIME_BINS):
            v = mat[r, b]
            color = _heat_color(v / peak if peak > 0 else 0.0)
            x = _LABEL_W + b * _CELL_W
            tb0 = t0 + span * b / TIME_BINS
            title = (f"{html.escape(label)} @ {tb0:.3f}s: "
                     f"{_fmt_bytes(v)}")
            out.append(
                f'<rect x="{x}" y="{y}" width="{_CELL_W - 1}" '
                f'height="{_CELL_H - 1}" fill="{color}">'
                f'<title>{title}</title></rect>')
    grid_h = nrows * _CELL_H
    for t, css, tip in markers:
        frac = min(max((t - t0) / span, 0.0), 1.0)
        x = _LABEL_W + frac * TIME_BINS * _CELL_W
        out.append(f'<line class="{css}" x1="{x:.1f}" y1="0" '
                   f'x2="{x:.1f}" y2="{grid_h}" stroke-width="2">'
                   f'<title>{html.escape(tip)}</title></line>')
    out.append(f'<text x="{_LABEL_W}" y="{grid_h + 16}">'
               f'{t0:.3f}s</text>')
    out.append(f'<text x="{_LABEL_W + TIME_BINS * _CELL_W}" '
               f'y="{grid_h + 16}" text-anchor="end">{t1:.3f}s</text>')
    out.append("</svg>")
    return "".join(out)


def _size_hist_svg(read_hist: Sequence[int],
                   write_hist: Sequence[int]) -> str:
    """The Darshan access-size histogram: paired read/write bars over
    the 10 ``SIZE_BIN_NAMES`` buckets."""
    bar_w, gap, height = 22, 16, 140
    peak = max(list(read_hist) + list(write_hist) + [1])
    w = len(SIZE_BIN_NAMES) * (2 * bar_w + gap) + 40
    h = height + 80
    out = [f'<svg id="size-hist" width="{w}" height="{h}" '
           f'xmlns="http://www.w3.org/2000/svg" font-family="monospace" '
           f'font-size="10">']
    for i, name in enumerate(SIZE_BIN_NAMES):
        x = 20 + i * (2 * bar_w + gap)
        for j, (hist, color) in enumerate(
                ((read_hist, "#365c8d"), (write_hist, "#f7b733"))):
            v = int(hist[i]) if i < len(hist) else 0
            bh = height * v / peak
            out.append(
                f'<rect x="{x + j * bar_w}" y="{height - bh + 10}" '
                f'width="{bar_w - 2}" height="{bh:.1f}" fill="{color}">'
                f'<title>{name} {"reads" if j == 0 else "writes"}: {v}'
                f'</title></rect>')
        short = name.replace("SIZE_", "")
        out.append(
            f'<text x="{x + bar_w}" y="{height + 24}" text-anchor="end" '
            f'transform="rotate(-45 {x + bar_w} {height + 24})">'
            f'{short}</text>')
    out.append(f'<text x="20" y="{h - 4}">'
               f'reads (blue) / writes (amber) per Darshan size bin'
               f'</text>')
    out.append("</svg>")
    return "".join(out)


def _findings_rows(findings) -> str:
    rows = []
    for f in findings:
        who = "fleet" if getattr(f, "rank", None) is None \
            else f"rank {f.rank}"
        rows.append(
            "<tr>"
            f"<td>{html.escape(f.detector)}</td>"
            f"<td>{who}</td>"
            f"<td>{f.severity:.2f}</td>"
            f"<td>{f.window[0]:.3f}&ndash;{f.window[1]:.3f}s</td>"
            f"<td>{html.escape(f.recommendation)}</td>"
            "</tr>")
    return "".join(rows)


def _tune_rows(audit: Sequence[dict]) -> str:
    rows = []
    for e in audit:
        a = e.get("action", {}) or {}
        who = ("fleet" if a.get("rank") is None
               else f"rank {a.get('rank')}")
        acks = ", ".join(f"r{k.get('rank')}:{k.get('status')}"
                         for k in e.get("acks", [])) or "&mdash;"
        rows.append(
            "<tr>"
            f"<td>{html.escape(str(a.get('kind', '?')))}</td>"
            f"<td>{html.escape(str(a.get('policy', '?')))}</td>"
            f"<td>{who}</td>"
            f"<td>{html.escape(str(e.get('status', '?')))}</td>"
            f"<td>{acks}</td>"
            "</tr>")
    return "".join(rows)


def _health_panel(health: dict) -> str:
    status = health.get("status", "ok")
    cls = "ok" if status == "ok" else "degraded"
    out = [f'<div id="health-panel" class="panel health-{cls}">',
           f'<h2>Self-telemetry health: '
           f'<span class="badge {cls}">{status}</span></h2>', "<ul>"]
    for label, check in sorted((health.get("checks") or {}).items()):
        ccls = "ok" if check.get("status") == "ok" else "degraded"
        out.append(
            f'<li class="check-{ccls}"><b>{html.escape(label)}</b>: '
            f'{check.get("status")} (value={check.get("value")}) '
            f'&mdash; {html.escape(str(check.get("detail", "")))}</li>')
    out.append("</ul></div>")
    return "".join(out)


def _metrics_table(metrics: dict) -> str:
    counters = (metrics or {}).get("counters") or {}
    gauges = (metrics or {}).get("gauges") or {}
    hists = (metrics or {}).get("histograms") or {}
    rows = []
    for name in sorted(counters):
        rows.append(f"<tr><td>{html.escape(name)}</td><td>counter</td>"
                    f"<td>{int(counters[name])}</td></tr>")
    for name in sorted(gauges):
        rows.append(f"<tr><td>{html.escape(name)}</td><td>gauge</td>"
                    f"<td>{gauges[name]:.6g}</td></tr>")
    for name in sorted(hists):
        h = hists[name] or {}
        rows.append(
            f"<tr><td>{html.escape(name)}</td><td>histogram</td>"
            f"<td>n={int(h.get('count', 0))}, "
            f"sum={float(h.get('sum', 0.0)):.6g}</td></tr>")
    return (
        '<table id="metrics"><thead><tr><th>metric</th><th>type</th>'
        '<th>value</th></tr></thead><tbody>'
        + ("".join(rows) or '<tr><td colspan="3">no metrics</td></tr>')
        + "</tbody></table>")


_STYLE = """
body { font-family: monospace; background: #0e1117; color: #dbe2ef;
       margin: 24px; }
h1, h2 { color: #f7b733; font-weight: normal; }
.panel { background: #161b26; border: 1px solid #2a3245;
         border-radius: 6px; padding: 12px 16px; margin: 14px 0; }
table { border-collapse: collapse; margin: 8px 0; }
td, th { border: 1px solid #2a3245; padding: 3px 10px;
         text-align: left; }
th { color: #9fb4d8; }
.badge.ok { color: #7bd389; }
.badge.degraded { color: #ff6b6b; }
li.check-degraded { color: #ff9f68; }
line.finding-marker { stroke: #ff6b6b; }
line.tune-marker { stroke: #7bd389; stroke-dasharray: 4 3; }
.meta { color: #9fb4d8; }
"""


def _report_window(cols) -> Tuple[float, float]:
    if len(cols) == 0:
        return (0.0, 0.0)
    return (float(np.min(cols.start)), float(np.max(cols.end)))


def _markers(findings, audit) -> List[Tuple[float, str, str]]:
    marks: List[Tuple[float, str, str]] = []
    for f in findings:
        marks.append((float(f.window[1]), "finding-marker",
                      f"{f.detector} (sev {f.severity:.2f}): "
                      f"{f.recommendation}"))
    for e in audit:
        a = e.get("action", {}) or {}
        t = a.get("issued_at")
        if not t:
            continue
        marks.append((float(t), "tune-marker",
                      f"tune {a.get('kind', '?')} ({a.get('policy', '?')})"
                      f" -> {e.get('status', '?')}"))
    return marks


def render_dashboard(report, path: Optional[str] = None) -> str:
    """Render ``report`` (a unified ``repro.profiler.Report``) as one
    offline HTML document; writes it to ``path`` when given and returns
    the HTML text either way.  A ``repro.warehouse.Archive`` works as
    a data source too — it adapts itself to the report surface."""
    if not hasattr(report, "segments_table") \
            and hasattr(report, "as_report"):
        report = report.as_report()    # repro.warehouse.Archive
    cols = report.segments_table()
    window = _report_window(cols)
    findings = list(report.findings)
    audit = list(getattr(report, "tune_audit", None) or [])
    marks = _markers(findings, audit)

    # per-file heatmap: top files by bytes moved
    npaths = len(cols.paths)
    if npaths and len(cols):
        per_path = np.zeros(npaths, dtype=np.float64)
        np.add.at(per_path, cols.path_ids,
                  np.asarray(cols.length, dtype=np.float64))
        top = np.argsort(per_path)[::-1][:MAX_FILE_ROWS]
        row_of_path = np.full(npaths, -1, dtype=np.int64)
        row_of_path[top] = np.arange(len(top))
        keep = row_of_path[cols.path_ids] >= 0
        sub = cols.data[keep]
        from repro.trace import SegmentColumns
        sub_cols = SegmentColumns(sub, cols.modules, cols.paths, cols.ops)
        file_mat = _bin_rows(sub_cols,
                             row_of_path[sub_cols.path_ids],
                             len(top), window)
        file_labels = [cols.paths[i] for i in top]
        dropped_files = npaths - len(top)
    else:
        file_mat = np.zeros((0, TIME_BINS))
        file_labels, dropped_files = [], 0

    # per-rank heatmap: fleet slices, or the one local timeline
    ranks = report.ranks
    if ranks:
        rank_ids = sorted(ranks)
        rank_labels = [f"rank {r}" for r in rank_ids]
        mats = []
        for r in rank_ids:
            rc = ranks[r].segments_table()
            mats.append(_bin_rows(rc, np.zeros(len(rc), dtype=np.int64),
                                  1, window)[0])
        rank_mat = (np.vstack(mats) if mats
                    else np.zeros((0, TIME_BINS)))
    else:
        rank_labels = ["rank 0"]
        rank_mat = _bin_rows(cols, np.zeros(len(cols), dtype=np.int64),
                             1, window)

    p = report.posix
    health = report.health()
    metrics = report.metrics

    file_note = (f'<p class="meta">{dropped_files} more file(s) below '
                 f'the top {MAX_FILE_ROWS} not shown</p>'
                 if dropped_files > 0 else "")
    parts = [
        "<!DOCTYPE html>",
        '<html><head><meta charset="utf-8">',
        "<title>tf-darshan dashboard</title>",
        f"<style>{_STYLE}</style></head><body>",
        "<h1>tf-darshan dashboard</h1>",
        f'<p class="meta">mode={report.mode} nprocs={report.nprocs} '
        f'elapsed={report.elapsed_s:.3f}s '
        f'bandwidth={report.bandwidth_mb_s:.1f} MB/s '
        f'segments={len(cols)} window=[{window[0]:.3f}, '
        f'{window[1]:.3f}]s</p>',
        _health_panel(health),
        '<div class="panel"><h2>Per-file bandwidth timeline</h2>',
        _heatmap_svg("per-file-heatmap", file_labels, file_mat, window,
                     markers=marks),
        file_note,
        "</div>",
        '<div class="panel"><h2>Per-rank bandwidth timeline</h2>',
        _heatmap_svg("per-rank-heatmap", rank_labels, rank_mat, window,
                     markers=marks),
        "</div>",
        '<div class="panel"><h2>Access sizes (Darshan bins)</h2>',
        _size_hist_svg(p.read_size_hist, p.write_size_hist),
        "</div>",
        '<div class="panel"><h2>Insight findings</h2>',
        '<table id="findings"><thead><tr><th>detector</th><th>scope</th>'
        '<th>severity</th><th>window</th><th>recommendation</th></tr>'
        "</thead><tbody>"
        + (_findings_rows(findings)
           or '<tr><td colspan="5">no findings</td></tr>')
        + "</tbody></table></div>",
        '<div class="panel"><h2>Tune-action audit</h2>',
        '<table id="tune-audit"><thead><tr><th>kind</th><th>policy</th>'
        '<th>scope</th><th>status</th><th>acks</th></tr></thead><tbody>'
        + (_tune_rows(audit)
           or '<tr><td colspan="5">no tune actions</td></tr>')
        + "</tbody></table></div>",
        '<div class="panel"><h2>Self-telemetry metrics</h2>',
        _metrics_table(metrics),
        "</div>",
        # the raw numbers ride along so the file doubles as a data
        # capture (tooling can re-plot without re-running anything)
        '<script type="application/json" id="dashboard-data">',
        json.dumps({"health": health, "metrics": metrics,
                    "window": list(window),
                    "findings": [f.to_dict() for f in findings]}),
        "</script>",
        "</body></html>",
    ]
    text = "\n".join(parts)
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text
