"""MetricsRegistry: lock-cheap self-telemetry counters for the profiler.

Three instrument types, all safe for concurrent writers:

  * ``Counter``   — monotonic int (``inc``); drops, errors, bytes,
    reconnects.
  * ``Gauge``     — last-written float (``set``); staleness, lag,
    rates.
  * ``Histogram`` — bounded-bucket distribution (``observe``); the
    bucket bounds default to the Darshan access-size bins
    (``repro.core.counters.SIZE_BIN_BOUNDS``), so a byte-sized
    observation lands in the same 10 bins the POSIX module uses.
    Latency histograms observe **nanoseconds** against the same bounds
    (100 ns, 1 µs, 10 µs, ... 1 s+) — one bin vocabulary everywhere.

Each instrument carries its own lock: an uncontended ``inc`` is two
attribute loads and an add (~100 ns), cheap enough for per-append
paths; genuinely per-op hot paths (``DarshanRuntime._emit``) sample.

Reads are ``snapshot()`` — one plain-dict copy of everything —
with ``snapshot_delta`` for windowed views (what a ProfileSession
attaches to its report) and ``merge_snapshots`` for the fleet rollup
(counters and histogram buckets add across ranks; gauges take the max,
the "worst level" convention).

Naming convention (dotted, subsystem-first): ``trace.dropped``,
``runtime.listener_errors``, ``runtime.emit_ns``, ``link.tcp.resends``,
``collector.lines``, ``insight.poll_lag_s``, ``tune.applier.failed``.
``health_summary`` keys off these names to produce the ok/degraded
panel the dashboard renders.

``default_registry()`` is the process-global registry for components
with no natural owner (transports); per-rank components
(``DarshanRuntime``) own private registries so simulated fleets —
N ranks in one process — keep per-rank telemetry separate.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.counters import SIZE_BIN_BOUNDS


class Counter:
    """Monotonic integer. ``inc`` under a per-instrument lock so
    concurrent writers never lose counts."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Gauge:
    """Last-written float level (staleness, lag, rate)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self._value})"


class Histogram:
    """Bounded-bucket distribution over the Darshan access-size bins.

    ``bounds`` are the right-open bucket edges: an observation ``v``
    lands in bucket ``bisect_right(bounds, v)`` — exactly
    ``repro.core.counters.size_bin`` when the default bounds are used,
    so ``counts`` always has ``len(bounds) + 1`` buckets and their sum
    equals the observation count (the invariant the property tests
    pin)."""

    __slots__ = ("name", "bounds", "_counts", "_count", "_sum", "_lock")

    def __init__(self, name: str,
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = tuple(bounds if bounds is not None
                            else SIZE_BIN_BOUNDS)
        self._counts = [0] * (len(self.bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v) -> None:
        i = bisect_right(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def to_dict(self) -> dict:
        with self._lock:
            return {"counts": list(self._counts), "count": self._count,
                    "sum": self._sum}

    def __repr__(self) -> str:
        return f"Histogram({self.name}, n={self._count})"


class MetricsRegistry:
    """Named instruments, created on first use (``counter(name)`` etc.
    get-or-create; asking for an existing name with a different type
    raises)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------- instruments
    def _get(self, table: dict, name: str, make):
        m = table.get(name)
        if m is not None:
            return m
        with self._lock:
            self._check_free(name, table)
            m = table.get(name)
            if m is None:
                m = table[name] = make()
            return m

    def _check_free(self, name: str, table: dict) -> None:
        for other in (self._counters, self._gauges, self._histograms):
            if other is not table and name in other:
                raise ValueError(
                    f"metric name {name!r} already registered as a "
                    f"different instrument type")

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, lambda: Gauge(name))

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(self._histograms, name,
                         lambda: Histogram(name, bounds=bounds))

    # ------------------------------------------------------------- reads
    def snapshot(self) -> dict:
        """Everything, as one JSON-ready plain dict (the wire/rollup
        shape)."""
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.values())
        return {
            "counters": {c.name: c.value for c in counters},
            "gauges": {g.name: g.value for g in gauges},
            "histograms": {h.name: h.to_dict() for h in hists},
        }

    def delta(self, mark: Optional[dict]) -> dict:
        """The change since ``mark`` (an earlier ``snapshot()``)."""
        return snapshot_delta(mark, self.snapshot())


# ------------------------------------------------------- snapshot algebra
def empty_snapshot() -> dict:
    return {"counters": {}, "gauges": {}, "histograms": {}}


def copy_snapshot(snap: Optional[dict]) -> dict:
    snap = snap or {}
    return {
        "counters": dict(snap.get("counters", {})),
        "gauges": dict(snap.get("gauges", {})),
        "histograms": {k: {"counts": list(v.get("counts", [])),
                           "count": v.get("count", 0),
                           "sum": v.get("sum", 0.0)}
                       for k, v in snap.get("histograms", {}).items()},
    }


def snapshot_delta(old: Optional[dict], new: dict) -> dict:
    """Counter and histogram *changes* from ``old`` to ``new``; gauges
    are levels, so the new value stands.  Instruments created after
    ``old`` appear whole."""
    if not old:
        return copy_snapshot(new)
    out = empty_snapshot()
    oc = old.get("counters", {})
    for k, v in new.get("counters", {}).items():
        out["counters"][k] = v - oc.get(k, 0)
    out["gauges"] = dict(new.get("gauges", {}))
    oh = old.get("histograms", {})
    for k, h in new.get("histograms", {}).items():
        prev = oh.get(k, {})
        pcounts = prev.get("counts", [])
        out["histograms"][k] = {
            "counts": [c - (pcounts[i] if i < len(pcounts) else 0)
                       for i, c in enumerate(h.get("counts", []))],
            "count": h.get("count", 0) - prev.get("count", 0),
            "sum": h.get("sum", 0.0) - prev.get("sum", 0.0),
        }
    return out


def merge_snapshots(snaps: Iterable[Optional[dict]]) -> dict:
    """The fleet rollup: counters and histogram buckets sum across
    snapshots (additive telemetry, Darshan's job-level convention);
    gauges take the max — the worst level wins, which is what a health
    panel wants from staleness/lag."""
    out = empty_snapshot()
    for snap in snaps:
        if not snap:
            continue
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        for k, v in snap.get("gauges", {}).items():
            prev = out["gauges"].get(k)
            out["gauges"][k] = v if prev is None else max(prev, v)
        for k, h in snap.get("histograms", {}).items():
            tgt = out["histograms"].get(k)
            if tgt is None:
                out["histograms"][k] = {
                    "counts": list(h.get("counts", [])),
                    "count": h.get("count", 0),
                    "sum": h.get("sum", 0.0)}
                continue
            counts = h.get("counts", [])
            tc = tgt["counts"]
            if len(counts) > len(tc):
                tc.extend([0] * (len(counts) - len(tc)))
            for i, c in enumerate(counts):
                tc[i] += c
            tgt["count"] += h.get("count", 0)
            tgt["sum"] += h.get("sum", 0.0)
    return out


# --------------------------------------------------------- global registry
_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-global registry (transports and other components with
    no per-rank owner)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = MetricsRegistry()
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Fresh process-global registry (tests)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


# ----------------------------------------------------------------- health
# (check label, summed counter names, what a non-zero value means)
_HEALTH_CHECKS: Tuple[Tuple[str, Tuple[str, ...], str], ...] = (
    ("trace-drops", ("trace.dropped",),
     "trace ring overwrote unread segments (raise dxt_capacity)"),
    ("listener-errors", ("runtime.listener_errors",),
     "segment listeners raised (a detector is crashing)"),
    ("insight-drops", ("insight.ring_dropped",),
     "insight fell behind the trace ring (shorten insight_interval_s)"),
    ("tcp-retries", ("link.tcp.reconnects", "link.tcp.resends"),
     "TCP exchanges were retried (idle reaps or an unstable collector)"),
    ("ingest-errors", ("collector.errors", "collector.corrupt_lines"),
     "collector hit malformed/corrupt wire lines"),
    ("tune-failures", ("tune.rejected", "tune.applier.failed",
                       "tune.applier.rejected"),
     "tune actions failed or were rejected"),
    ("relay-drops", ("relay.dropped_reports", "relay.dropped_findings",
                     "relay.forward_errors"),
     "a relay tier dropped payloads or failed to forward upstream "
     "(raise max_pending or shorten relay_flush_interval_s)"),
)


def health_summary(metrics: Optional[dict],
                   listener_errors: Optional[dict] = None) -> dict:
    """Degraded/ok rollup over a metrics snapshot.

    Each check sums a fixed set of counter names; any positive sum
    degrades that check (and the overall status).  ``listener_errors``
    (the report-level dict) folds into the listener check so pre-metrics
    payloads still surface a crashing listener."""
    counters = (metrics or {}).get("counters", {})
    checks = {}
    degraded = False
    for label, names, meaning in _HEALTH_CHECKS:
        value = sum(int(counters.get(n, 0)) for n in names)
        if label == "listener-errors" and listener_errors:
            value += sum(int(v) for v in listener_errors.values())
        bad = value > 0
        degraded = degraded or bad
        checks[label] = {"status": "degraded" if bad else "ok",
                         "value": value, "detail": meaning}
    return {"status": "degraded" if degraded else "ok", "checks": checks}


# -------------------------------------------------------------- wire verb
def handle_metrics(endpoint, msg):
    """The ``metrics`` verb every ``repro.link`` Endpoint resolves
    through the plugin registry.

    Query (empty payload): replies with a ``metrics`` message carrying
    the context's snapshot — a FleetCollector answers with its own
    registry, a ProfileServer with its session runtime's, anything else
    with the process default.

    Push (``{"push": true, "metrics": {...}}``): stores the snapshot on
    the sender's rank slice when the context aggregates ranks (the
    one-way spool path — a spool cannot answer a query, but a pushed
    line lands in the capture and replays into the collector)."""
    payload = msg.payload or {}
    ctx = endpoint.context
    if payload.get("push"):
        slice_of = getattr(ctx, "_slice", None)
        lock = getattr(ctx, "_lock", None)
        if slice_of is not None and lock is not None:
            snap = copy_snapshot(payload.get("metrics"))
            with lock:
                slice_of(msg.rank).metrics = snap
        return msg.reply("ok")
    reg = getattr(ctx, "metrics", None)
    if not isinstance(reg, MetricsRegistry):
        session = getattr(ctx, "session", None)
        rt = getattr(session, "rt", None) or getattr(ctx, "rt", None)
        reg = getattr(rt, "metrics", None)
    if not isinstance(reg, MetricsRegistry):
        reg = default_registry()
    return msg.reply("metrics", {"metrics": reg.snapshot()})
