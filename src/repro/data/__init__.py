from repro.data.dataset import FileDataset
from repro.data.pipeline import AUTOTUNE, Pipeline
from repro.data.readers import (READERS, posix_read_file, resolve_reader,
                                sized_read_file)
from repro.data.tiers import StorageTier, TierManager

__all__ = ["FileDataset", "AUTOTUNE", "Pipeline", "READERS",
           "posix_read_file", "resolve_reader", "sized_read_file",
           "StorageTier", "TierManager"]
