"""File readers used by the data pipeline.

``posix_read_file`` reproduces TensorFlow's ReadFile behaviour that the
paper diagnoses (§V-A): a loop of fixed-size preads that only terminates
on a zero-length read — every file costs (ceil(size/chunk) + 1) reads,
which is where the paper's "2x reads vs files opened, 50 % of reads are
0-100 B" signature comes from.

``sized_read_file`` is the profile-guided fix (beyond-paper, DESIGN.md
§8): stat first, then issue exactly the reads needed — no zero-length
tail read.

The ``repro.io`` ingest engine adds the fast paths: ``pooled`` (buffer-
pool + ``preadv`` gather, zero per-chunk allocation), ``mmap`` (page-
cache mapping), ``coalesced`` (many small files per pooled buffer —
the paper's ImageNet/malware shape), and ``adaptive`` (pooled with a
bandwidth-hill-climbed chunk size/io depth, drivable by ``repro.tune``
``io-chunk`` actions).  All entries keep the same signature and are
byte-exact with ``posix_read_file`` (property-tested), and all still go
through ``os.open/os.pread(v)`` so the attach layer (the GOT-patch
analogue) instruments them transparently; this module never imports
repro.core.
"""
from __future__ import annotations

import os
from typing import Callable, Optional, Union

from repro.io.adaptive import adaptive_read_file
from repro.io.buffers import pooled_read_file
from repro.io.coalesce import coalesced_read_file
from repro.io.readahead import mmap_read_file

DEFAULT_CHUNK = 1 << 20          # 1 MiB, like TF's ReadFile buffering


def posix_read_file(path: str, chunk_size: int = DEFAULT_CHUNK,
                    throttle=None) -> bytes:
    """Read-until-EOF loop (paper-faithful, with the zero-length tail)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        parts = []
        offset = 0
        while True:
            data = os.pread(fd, chunk_size, offset)
            if throttle is not None:
                throttle(len(data))
            if not data:                 # zero-length read signals EOF
                break
            parts.append(data)
            offset += len(data)
        return b"".join(parts)
    finally:
        os.close(fd)


def sized_read_file(path: str, chunk_size: int = DEFAULT_CHUNK,
                    throttle=None) -> bytes:
    """Size-aware reader: one stat + exactly ceil(size/chunk) preads."""
    size = os.stat(path).st_size
    fd = os.open(path, os.O_RDONLY)
    try:
        parts = []
        offset = 0
        while offset < size:
            data = os.pread(fd, min(chunk_size, size - offset), offset)
            if throttle is not None:
                throttle(len(data))
            if not data:
                break
            parts.append(data)
            offset += len(data)
        return b"".join(parts)
    finally:
        os.close(fd)


READERS = {
    "posix": posix_read_file,        # paper-faithful (zero-length tail)
    "sized": sized_read_file,        # profile-guided exact reads
    "pooled": pooled_read_file,      # buffer pool + preadv gather
    "mmap": mmap_read_file,          # page-cache mapping, large files
    "coalesced": coalesced_read_file,  # many small files per buffer
    "adaptive": adaptive_read_file,  # pooled + bandwidth hill-climb
}


def resolve_reader(reader: Union[str, Callable, None],
                   default: Callable = posix_read_file) -> Callable:
    """Accept a ``READERS`` key or a callable; ``None`` → ``default``.

    This is what lets ``Pipeline.map("coalesced", 16)`` and
    ``make_tiered_reader(tm, reader="pooled")`` take plain strings."""
    if reader is None:
        return default
    if callable(reader):
        return reader
    try:
        return READERS[reader]
    except KeyError:
        raise KeyError(f"unknown reader {reader!r} "
                       f"(one of {sorted(READERS)})") from None
