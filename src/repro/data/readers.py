"""File readers used by the data pipeline.

``posix_read_file`` reproduces TensorFlow's ReadFile behaviour that the
paper diagnoses (§V-A): a loop of fixed-size preads that only terminates
on a zero-length read — every file costs (ceil(size/chunk) + 1) reads,
which is where the paper's "2x reads vs files opened, 50 % of reads are
0-100 B" signature comes from.

``sized_read_file`` is the profile-guided fix (beyond-paper, DESIGN.md
§8): stat first, then issue exactly the reads needed — no zero-length
tail read.

Both go through ``os.open/os.pread`` so the attach layer (the GOT-patch
analogue) instruments them transparently; neither imports repro.core.
"""
from __future__ import annotations

import os
from typing import Optional

DEFAULT_CHUNK = 1 << 20          # 1 MiB, like TF's ReadFile buffering


def posix_read_file(path: str, chunk_size: int = DEFAULT_CHUNK,
                    throttle=None) -> bytes:
    """Read-until-EOF loop (paper-faithful, with the zero-length tail)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        parts = []
        offset = 0
        while True:
            data = os.pread(fd, chunk_size, offset)
            if throttle is not None:
                throttle(len(data))
            if not data:                 # zero-length read signals EOF
                break
            parts.append(data)
            offset += len(data)
        return b"".join(parts)
    finally:
        os.close(fd)


def sized_read_file(path: str, chunk_size: int = DEFAULT_CHUNK,
                    throttle=None) -> bytes:
    """Size-aware reader: one stat + exactly ceil(size/chunk) preads."""
    size = os.stat(path).st_size
    fd = os.open(path, os.O_RDONLY)
    try:
        parts = []
        offset = 0
        while offset < size:
            data = os.pread(fd, min(chunk_size, size - offset), offset)
            if throttle is not None:
                throttle(len(data))
            if not data:
                break
            parts.append(data)
            offset += len(data)
        return b"".join(parts)
    finally:
        os.close(fd)


READERS = {"posix": posix_read_file, "sized": sized_read_file}
