"""File-backed dataset abstraction with deterministic sharding/shuffling
for data-parallel training (each DP worker reads a disjoint shard —
the "independent I/O" pattern of ML workloads the paper contrasts with
HPC collective I/O)."""
from __future__ import annotations

import os
import random
from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Sequence


@dataclass(frozen=True)
class FileDataset:
    files: tuple
    labels: tuple = ()

    @staticmethod
    def from_dir(root: str, suffix: str = "") -> "FileDataset":
        out = []
        for dirpath, _, names in os.walk(root):
            for n in sorted(names):
                if n.endswith(suffix):
                    out.append(os.path.join(dirpath, n))
        out.sort()
        return FileDataset(tuple(out))

    def shard(self, num_shards: int, index: int) -> "FileDataset":
        """Deterministic round-robin shard; every file appears in exactly
        one shard (property-tested)."""
        if not 0 <= index < num_shards:
            raise ValueError(f"bad shard {index}/{num_shards}")
        files = self.files[index::num_shards]
        labels = self.labels[index::num_shards] if self.labels else ()
        return FileDataset(files, labels)

    def shuffle(self, seed: int) -> "FileDataset":
        idx = list(range(len(self.files)))
        random.Random(seed).shuffle(idx)
        files = tuple(self.files[i] for i in idx)
        labels = tuple(self.labels[i] for i in idx) if self.labels else ()
        return FileDataset(files, labels)

    def map_paths(self, fn: Callable[[str], str]) -> "FileDataset":
        """Apply a path resolver (e.g. StagingManager.resolve)."""
        return FileDataset(tuple(fn(f) for f in self.files), self.labels)

    def total_bytes(self) -> int:
        return sum(os.stat(f).st_size for f in self.files)

    def __len__(self) -> int:
        return len(self.files)
