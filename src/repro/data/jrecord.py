"""JRecord: a TFRecord-like container format (beyond-paper optimization,
DESIGN.md §8 — the paper's §VII discussion proposes containers to kill
the small-file metadata tail).

Layout per record:  u64 length | u32 crc32(payload) | payload bytes.
A sidecar index file (.idx) stores u64 offsets so readers can seek.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, List, Optional

MAGIC = b"JREC0001"
_LEN = struct.Struct("<Q")
_CRC = struct.Struct("<I")


class JRecordWriter:
    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "wb")
        self._f.write(MAGIC)
        self._offsets: List[int] = []

    def write(self, payload: bytes) -> None:
        self._offsets.append(self._f.tell())
        self._f.write(_LEN.pack(len(payload)))
        self._f.write(_CRC.pack(zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)

    def close(self) -> None:
        self._f.close()
        with open(self.path + ".idx", "wb") as f:
            f.write(_LEN.pack(len(self._offsets)))
            for off in self._offsets:
                f.write(_LEN.pack(off))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class JRecordReader:
    def __init__(self, path: str):
        self.path = path
        self._offsets: Optional[List[int]] = None

    def _load_index(self) -> List[int]:
        if self._offsets is None:
            with open(self.path + ".idx", "rb") as f:
                (n,) = _LEN.unpack(f.read(8))
                self._offsets = [
                    _LEN.unpack(f.read(8))[0] for _ in range(n)]
        return self._offsets

    def __len__(self) -> int:
        return len(self._load_index())

    def read(self, i: int) -> bytes:
        off = self._load_index()[i]
        fd = os.open(self.path, os.O_RDONLY)
        try:
            header = os.pread(fd, 12, off)
            (n,) = _LEN.unpack(header[:8])
            (crc,) = _CRC.unpack(header[8:12])
            payload = os.pread(fd, n, off + 12)
        finally:
            os.close(fd)
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            raise IOError(f"crc mismatch in {self.path}[{i}]")
        return payload

    def __iter__(self) -> Iterator[bytes]:
        """Sequential scan (one open, large sequential reads)."""
        with open(self.path, "rb") as f:
            magic = f.read(len(MAGIC))
            if magic != MAGIC:
                raise IOError(f"bad magic in {self.path}")
            while True:
                header = f.read(12)
                if len(header) < 12:
                    return
                (n,) = _LEN.unpack(header[:8])
                (crc,) = _CRC.unpack(header[8:12])
                payload = f.read(n)
                if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                    raise IOError(f"crc mismatch in {self.path}")
                yield payload


def pack_files(file_paths, out_path: str, read_fn=None) -> int:
    """Pack many small files into one JRecord shard; returns bytes packed."""
    from repro.data.readers import sized_read_file
    read_fn = read_fn or sized_read_file
    total = 0
    with JRecordWriter(out_path) as w:
        for p in file_paths:
            data = read_fn(p)
            w.write(data)
            total += len(data)
    return total
