"""Multi-tier storage model.

A tier is a directory plus an optional token-bucket bandwidth throttle so
HDD / SSD / Optane-class tiers behave deterministically on this
container's single disk (the *policy* — what to stage where — is the
paper's contribution; the tier hardware is simulated, DESIGN.md §2).
``/dev/shm`` serves as a genuine fast tier for live runs."""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional


class TokenBucket:
    """Simple bandwidth limiter: ``take(n)`` blocks until n bytes fit."""

    def __init__(self, bytes_per_s: float, burst: Optional[float] = None):
        self.rate = float(bytes_per_s)
        # burst sized to ~10 ms of bandwidth so per-file reads see the
        # steady-state rate, not a free initial window
        self.burst = burst or max(self.rate / 100, 1 << 20)
        self._tokens = self.burst
        self._t = time.perf_counter()
        self._lock = threading.Lock()

    def take(self, n: int) -> None:
        """Debt-based limiter: always admits the request, then sleeps long
        enough that sustained throughput equals the configured rate (large
        single requests simply incur a proportionally longer sleep)."""
        if n <= 0:
            return
        with self._lock:
            now = time.perf_counter()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            self._tokens -= n
            debt = -self._tokens
        if debt > 0:
            time.sleep(debt / self.rate)


@dataclass
class StorageTier:
    name: str
    root: str
    bandwidth_bytes_s: Optional[float] = None   # None = unthrottled
    open_latency_s: float = 0.0                 # seek / metadata cost
    # True: seeks occupy the (single) device head — HDD-like, concurrency
    # makes interleaving WORSE.  False: latency is per-request (parallel
    # file system metadata RTT) — concurrency hides it.
    seek_serialized: bool = False
    _bucket: Optional[TokenBucket] = None

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        if self.bandwidth_bytes_s:
            self._bucket = TokenBucket(self.bandwidth_bytes_s)
        self._last_path = None
        self._seek_lock = threading.Lock()

    def throttle(self, nbytes: int) -> None:
        if self._bucket is not None:
            self._bucket.take(nbytes)

    def note_access(self, path: Optional[str]) -> None:
        """HDD head model: switching between files costs a seek.  With one
        sequential reader this fires once per file; with many concurrent
        readers on large files, interleaved chunks thrash the head — the
        paper's Fig 11a large-file threading regression."""
        if self.open_latency_s <= 0 or path is None:
            return
        with self._seek_lock:
            switched = self._last_path != path
            self._last_path = path
        if switched:
            if self.seek_serialized and self._bucket is not None:
                # a head seek steals device time from everyone
                self._bucket.take(int(self.open_latency_s
                                      * self._bucket.rate))
            else:
                time.sleep(self.open_latency_s)

    def on_open(self, path: Optional[str] = None) -> None:
        self.note_access(path if path is not None else object())


class TierManager:
    """Resolves a file path to its tier (by root prefix) and provides the
    per-tier throttle callable the readers apply."""

    def __init__(self, tiers: Dict[str, StorageTier]):
        self.tiers = tiers
        self._by_root = sorted(tiers.values(), key=lambda t: -len(t.root))

    def tier_of(self, path: str) -> Optional[StorageTier]:
        for t in self._by_root:
            if path.startswith(t.root.rstrip("/") + "/") or path == t.root:
                return t
        return None

    def throttle_for(self, path: str):
        t = self.tier_of(path)
        if t is None or t._bucket is None:
            return None
        return t.throttle


def default_tiers(base: str, throttled: bool = False) -> TierManager:
    """hdd/ssd/optane tier layout; throttled=True gives HDD 120 MB/s with
    a per-open seek penalty, SSD 500 MB/s, Optane 2 GB/s-class
    deterministic behaviour (the paper's Greendog storage mix)."""
    def mk(name, bw, lat, serial=False):
        return StorageTier(name, os.path.join(base, name),
                           bandwidth_bytes_s=bw if throttled else None,
                           open_latency_s=lat if throttled else 0.0,
                           seek_serialized=serial)
    return TierManager({
        "hdd": mk("hdd", 120e6, 0.008, serial=True),
        "lustre": mk("lustre", 500e6, 0.008),       # metadata RTT, parallel
        "ssd": mk("ssd", 500e6, 0.0002),
        "optane": mk("optane", 2000e6, 0.00002),
    })


def make_tiered_reader(tm: TierManager, reader=None, resolver=None):
    """Reader that applies tier throttling/seek penalties and an optional
    path resolver (e.g. StagingManager.resolve for staged files).
    ``reader`` may be a callable or a ``READERS`` key ("pooled",
    "coalesced", ...); default is the paper-faithful posix reader."""
    from repro.data.readers import posix_read_file, resolve_reader
    reader = resolve_reader(reader, default=posix_read_file)
    def read(path: str):
        p = resolver(path) if resolver else path
        tier = tm.tier_of(p)
        if tier is None:
            return reader(p)

        def thr(n: int, _p=p, _t=tier):
            _t.note_access(_p)
            _t.throttle(n)

        tier.note_access(p)
        return reader(p, throttle=thr)
    return read
