"""tf.data-style input pipeline: parallel map (capture functions on a
thread pool), batching, prefetch, optional hedged re-dispatch of straggler
reads, and AUTOTUNE (profile-guided parallelism via the tf-Darshan
advisor — the paper's proposed runtime auto-tuning).

Semantics follow tf.data.map + prefetch: ``num_parallel_calls`` capture
functions execute concurrently on worker threads, results are consumed in
order, and a prefetch buffer of ``prefetch`` batches is kept filled by a
background thread so ingestion overlaps the accelerator step.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, replace
from typing import Any, Callable, Iterable, List, Optional, Sequence, Union

AUTOTUNE = -1


class PipelineControl:
    """Thread-safe external control handle for AUTOTUNE pipelines.

    ``_mapped_autotune`` polls it between windows: the pipeline
    publishes the thread count each window actually ran with
    (``note_threads`` -> ``current_threads``), and an outside party —
    the closed-loop ``repro.tune`` applier, or any local code — can
    ``request_threads(n)``; the request wins over the hill-climb/bias
    advice for the next window, then the climb continues from there.
    One handle may be shared across threads; requests are
    take-once (``take_request``)."""

    def __init__(self, threads: int = 0):
        self._lock = threading.Lock()
        self._current = int(threads)
        self._requested: Optional[int] = None

    @property
    def current_threads(self) -> int:
        """The thread count of the most recent window (0 before the
        first window runs)."""
        with self._lock:
            return self._current

    def note_threads(self, n: int) -> None:
        """Pipeline-side: publish the count the window runs with."""
        with self._lock:
            self._current = int(n)

    def request_threads(self, n: int) -> None:
        """Ask the pipeline to run its next window with ``n`` threads
        (clamped to >= 1).  The latest request before a window boundary
        wins."""
        with self._lock:
            self._requested = max(int(n), 1)

    def take_request(self) -> Optional[int]:
        """Pipeline-side: consume the pending request, if any."""
        with self._lock:
            req, self._requested = self._requested, None
            return req


@dataclass(frozen=True)
class _Spec:
    items: Sequence
    map_fn: Optional[Callable] = None
    num_parallel_calls: int = 1
    batch_size: Optional[int] = None
    prefetch_depth: int = 0
    hedge_timeout_s: Optional[float] = None
    autotune_window: int = 64
    autotune_start: int = 4
    drop_remainder: bool = False
    insight_engine: Optional[Any] = None
    control: Optional[PipelineControl] = None


class Pipeline:
    """Builder: Pipeline(ds.files).map(fn, N).batch(b).prefetch(k)."""

    def __init__(self, items: Sequence, _spec: Optional[_Spec] = None):
        self.spec = _spec or _Spec(items=items)

    def map(self, fn: Union[Callable, str],
            num_parallel_calls: int = 1) -> "Pipeline":
        """Map a capture function over the items.  ``fn`` may be a
        ``READERS`` key (``"posix"``, ``"sized"``, ``"pooled"``,
        ``"mmap"``, ``"coalesced"``, ``"adaptive"``) as well as any
        callable: ``Pipeline(paths).map("coalesced", 16)``."""
        if isinstance(fn, str):
            from repro.data.readers import resolve_reader
            fn = resolve_reader(fn)
        return Pipeline(None, replace(self.spec, map_fn=fn,
                                      num_parallel_calls=num_parallel_calls))

    def batch(self, size: int, drop_remainder: bool = False) -> "Pipeline":
        return Pipeline(None, replace(self.spec, batch_size=size,
                                      drop_remainder=drop_remainder))

    def prefetch(self, depth: int) -> "Pipeline":
        return Pipeline(None, replace(self.spec, prefetch_depth=depth))

    def hedge(self, timeout_s: float) -> "Pipeline":
        """Straggler mitigation: re-dispatch an element whose capture
        function hasn't finished within timeout_s; first result wins."""
        return Pipeline(None, replace(self.spec, hedge_timeout_s=timeout_s))

    def with_profiler(self, profiler) -> "Pipeline":
        """Wire live insight into AUTOTUNE: each autotune window polls
        the profiler's insight engine and lets streamed findings
        (small-file storm, straggler tail, tier saturation) override the
        pure bandwidth hill-climb — the paper's proposed profile-guided
        runtime loop.  Accepts a ``repro.profiler.Profiler`` (its
        ``insight_engine``, which must be enabled in its options) or a
        bare ``InsightEngine``."""
        engine = getattr(profiler, "insight_engine", profiler)
        if engine is None:
            raise ValueError(
                "with_profiler() needs insight enabled: construct the "
                "Profiler with ProfilerOptions(insight=True)")
        return Pipeline(None, replace(self.spec, insight_engine=engine))

    def with_control(self, control: PipelineControl) -> "Pipeline":
        """Attach an external ``PipelineControl`` handle that AUTOTUNE
        polls between windows — the closed-loop tuning hook
        (``repro.tune`` resize-threads actions land here), equally
        usable by local code."""
        return Pipeline(None, replace(self.spec, control=control))

    def with_insight(self, engine) -> "Pipeline":
        """Deprecated shim for ``with_profiler`` (same behavior)."""
        warnings.warn(
            "Pipeline.with_insight(engine) is deprecated; use "
            "Pipeline.with_profiler(profiler) with a repro.profiler."
            "Profiler (or pass the engine to with_profiler directly)",
            DeprecationWarning, stacklevel=2)
        return self.with_profiler(engine)

    # ------------------------------------------------------------------ run
    def __iter__(self):
        spec = self.spec
        if spec.batch_size is None:
            return self._iter_elements()
        return self._iter_batches()

    def _iter_batches(self):
        spec = self.spec
        buf: List[Any] = []
        for item in self._iter_elements():
            buf.append(item)
            if len(buf) == spec.batch_size:
                yield buf
                buf = []
        if buf and not spec.drop_remainder:
            yield buf

    def _iter_elements(self):
        spec = self.spec
        if spec.map_fn is None:
            yield from spec.items
            return
        if spec.prefetch_depth > 0:
            yield from self._prefetched(self._mapped())
        else:
            yield from self._mapped()

    def _prefetched(self, source):
        """Background thread keeps a bounded queue of ready elements.

        The feeder must not outlive the consumer: when the consumer
        abandons the iterator early (``break``, GC, generator
        ``close()``), a plain blocking ``q.put`` would park the daemon
        thread forever with the source — and whatever files/pools it
        holds — pinned.  So puts poll a stop event, the consumer's
        ``finally`` (run on close/GC) sets it and drains the queue,
        and the feeder closes the source generator from its own thread
        so upstream ``finally`` blocks (thread pools, leases) run."""
        spec = self.spec
        cap = max(spec.prefetch_depth * max(spec.batch_size or 1, 1), 1)
        q: "queue.Queue" = queue.Queue(maxsize=cap)
        DONE, ERR = object(), object()
        stop = threading.Event()

        def put(x) -> bool:
            while not stop.is_set():
                try:
                    q.put(x, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def feed():
            try:
                try:
                    for x in source:
                        if not put(x):
                            break
                    else:
                        put(DONE)
                finally:
                    close = getattr(source, "close", None)
                    if close is not None:
                        close()
            except BaseException as e:  # noqa: BLE001
                put((ERR, e))

        t = threading.Thread(target=feed, daemon=True,
                             name="repro-prefetch-feeder")
        t.start()
        try:
            while True:
                x = q.get()
                if x is DONE:
                    break
                if isinstance(x, tuple) and len(x) == 2 and x[0] is ERR:
                    raise x[1]
                yield x
        finally:
            stop.set()
            try:                      # wake a feeder parked on a full queue
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=5.0)

    def _mapped(self):
        spec = self.spec
        n = spec.num_parallel_calls
        if n == AUTOTUNE:
            yield from self._mapped_autotune()
            return
        n = max(n, 1)
        if n == 1 and spec.hedge_timeout_s is None:
            for it in spec.items:
                yield spec.map_fn(it)
            return
        pool = ThreadPoolExecutor(max_workers=n)
        try:
            yield from _ordered_parallel(pool, spec.map_fn, spec.items,
                                         in_flight=n + 2,
                                         hedge_timeout=spec.hedge_timeout_s)
        finally:
            # don't block on abandoned hedge originals still sleeping
            pool.shutdown(wait=False, cancel_futures=True)

    def _mapped_autotune(self):
        """Windowed hill-climbing on measured throughput (bytes/s when map
        results have a length, else items/s)."""
        from repro.core.advisor import ThreadAutotuneAdvisor
        spec = self.spec
        advisor = ThreadAutotuneAdvisor(start=spec.autotune_start)
        threads = spec.autotune_start
        items = list(spec.items)
        i = 0
        while i < len(items):
            window = items[i:i + spec.autotune_window]
            i += len(window)
            if spec.control is not None:
                spec.control.note_threads(threads)
            t0 = time.perf_counter()
            nbytes = 0
            with ThreadPoolExecutor(max_workers=threads) as pool:
                for res in _ordered_parallel(pool, spec.map_fn, window,
                                             in_flight=threads + 2,
                                             hedge_timeout=spec.hedge_timeout_s):
                    try:
                        nbytes += len(res)
                    except TypeError:
                        nbytes += 1
                    yield res
            dt = max(time.perf_counter() - t0, 1e-9)
            advice = advisor.observe(threads, nbytes / dt / 1e6)
            if spec.insight_engine is not None:
                spec.insight_engine.poll()
                biased = advisor.bias_from_findings(
                    spec.insight_engine.active_findings())
                if biased is not None:
                    advice = biased
            threads = advice.threads
            if spec.control is not None:
                # an external request (closed-loop tuning) speaks last:
                # it overrides this window's advice, then the climb
                # continues from the requested count
                requested = spec.control.take_request()
                if requested is not None:
                    advisor.current = requested
                    threads = requested


def _ordered_parallel(pool: ThreadPoolExecutor, fn, items,
                      in_flight: int, hedge_timeout: Optional[float]):
    """Submit up to ``in_flight`` tasks ahead, yield results in order;
    optionally hedge stragglers with a duplicate submission."""
    items = list(items)
    futures: dict = {}
    nxt = 0

    def ensure(k):
        nonlocal nxt
        while nxt < min(k + in_flight, len(items)):
            futures[nxt] = pool.submit(fn, items[nxt])
            nxt += 1

    for k in range(len(items)):
        ensure(k)
        f = futures.pop(k)
        if hedge_timeout is not None:
            try:
                yield f.result(timeout=hedge_timeout)
                continue
            except TimeoutError:
                backup = pool.submit(fn, items[k])
                winner = _first_done(f, backup)
                yield winner.result()
                continue
        yield f.result()


def _first_done(*fs: Future):
    import concurrent.futures as cf
    done, _ = cf.wait(fs, return_when=cf.FIRST_COMPLETED)
    return next(iter(done))
