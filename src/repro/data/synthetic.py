"""Synthetic datasets reproducing the paper's two case-study workloads:

* imagenet-like — many small files, log-normal sizes, median ~88 KB
  (paper: 128K files, 11.6 GB, median 88 KB; we scale counts/sizes down
  for CI but keep the distribution shape), and
* malware-like  — fewer, larger files, median ~4 MB with a sub-2MB tail
  that is ~40 % of files but only ~8 % of bytes (paper §V-B) — the tail
  the staging advisor must discover.
"""
from __future__ import annotations

import os
import numpy as np


def _write(path: str, n: int, rng: np.random.Generator) -> None:
    with open(path, "wb") as f:
        f.write(rng.bytes(n))


def make_imagenet_like(root: str, n_files: int = 512,
                       median_bytes: int = 88 * 1024,
                       sigma: float = 0.5, seed: int = 0) -> list:
    """Log-normal sizes around the ImageNet JPEG median."""
    rng = np.random.default_rng(seed)
    os.makedirs(root, exist_ok=True)
    sizes = np.exp(rng.normal(np.log(median_bytes), sigma, n_files))
    sizes = np.clip(sizes, 1024, 20 * median_bytes).astype(int)
    paths = []
    for i, n in enumerate(sizes):
        p = os.path.join(root, f"img_{i:06d}.jpg")
        _write(p, int(n), rng)
        paths.append(p)
    return paths


def make_malware_like(root: str, n_files: int = 64,
                      median_bytes: int = 4 * 1024 * 1024,
                      small_frac: float = 0.4, seed: int = 0) -> list:
    """~(1-small_frac) large files around the median + a small_frac tail
    below 2 MB that carries only a few % of total bytes."""
    rng = np.random.default_rng(seed)
    os.makedirs(root, exist_ok=True)
    n_small = int(n_files * small_frac)
    n_large = n_files - n_small
    large = np.exp(rng.normal(np.log(median_bytes), 0.4, n_large))
    large = np.clip(large, 2 * 1024 * 1024 + 1, 8 * median_bytes).astype(int)
    small = np.exp(rng.normal(np.log(300 * 1024), 0.8, n_small))
    small = np.clip(small, 8 * 1024, 2 * 1024 * 1024 - 1).astype(int)
    sizes = np.concatenate([large, small])
    rng.shuffle(sizes)
    paths = []
    for i, n in enumerate(sizes):
        p = os.path.join(root, f"mal_{i:05d}.bytes")
        _write(p, int(n), rng)
        paths.append(p)
    return paths


def make_token_shards(root: str, n_shards: int = 8,
                      docs_per_shard: int = 64,
                      mean_doc_tokens: int = 512,
                      vocab_size: int = 50_000, seed: int = 0) -> list:
    """LM training corpus as JRecord shards of token documents."""
    from repro.data.jrecord import JRecordWriter
    rng = np.random.default_rng(seed)
    os.makedirs(root, exist_ok=True)
    paths = []
    for s in range(n_shards):
        p = os.path.join(root, f"tokens_{s:04d}.jrec")
        with JRecordWriter(p) as w:
            for _ in range(docs_per_shard):
                n = max(16, int(rng.exponential(mean_doc_tokens)))
                toks = rng.integers(0, vocab_size, n, dtype=np.int32)
                w.write(toks.tobytes())
        paths.append(p)
    return paths
