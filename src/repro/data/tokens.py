"""Token pipeline: JRecord document shards -> fixed-length LM batches.

Documents are concatenated and packed into (batch, seq_len+1) windows
(inputs + shifted labels come from the same window).  Sharding is by
file round-robin per DP worker; the reader path goes through os.pread so
tf-Darshan instruments training-data ingestion end to end.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from repro.data.jrecord import JRecordReader


def token_batches(shard_paths: List[str], batch_size: int, seq_len: int,
                  vocab_size: int, seed: int = 0,
                  repeat: bool = True) -> Iterator[np.ndarray]:
    """Yields int32 (batch_size, seq_len + 1) token windows forever
    (or once if repeat=False)."""
    rng = np.random.default_rng(seed)
    window = seq_len + 1
    buf = np.empty((0,), np.int32)
    epoch = 0
    while True:
        order = rng.permutation(len(shard_paths))
        for si in order:
            reader = JRecordReader(shard_paths[si])
            for payload in reader:
                doc = np.frombuffer(payload, np.int32) % vocab_size
                buf = np.concatenate([buf, doc])
                while len(buf) >= batch_size * window:
                    take = buf[:batch_size * window]
                    buf = buf[batch_size * window:]
                    yield take.reshape(batch_size, window).copy()
        epoch += 1
        if not repeat:
            return
