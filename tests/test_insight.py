"""End-to-end and unit tests for the repro.insight streaming diagnosis
engine: each synthetic pathology workload must trigger its detector and
ONLY that detector, findings must flow through session stop into both
exporters, and the runtime hook must attach/detach without leaking."""
import os
import random

import pytest

from repro.core import (ProfileSession, reset_runtime, to_chrome_trace,
                        to_json_report)
from repro.core.advisor import StagingAdvisor, ThreadAutotuneAdvisor
from repro.core.analysis import analyze
from repro.core.dxt import Segment
from repro.core.records import FileRecord
from repro.insight import EventBus, Finding, InsightEngine, extract
from repro.insight.detectors import (FastTierSaturationDetector,
                                     StragglerReadTailDetector)


def _profiled(rt, workload, attempts: int = 4) -> "SessionReport":
    # Long poll interval => one deterministic window per session (the
    # final poll in stop()); evidence counts then cover the whole
    # workload instead of whichever slice a background tick left last.
    #
    # A loaded CI container can stall µs-scale reads to ms-scale, which
    # the straggler detector correctly reports as real latency
    # dispersion.  Retry for a quiet run; a genuine discrimination bug
    # fires on every attempt and still fails the caller's assertion.
    for _ in range(attempts):
        from repro.core import reset_runtime as _reset
        rt = _reset()
        sess = ProfileSession(rt, insight=True, insight_interval_s=60.0)
        with sess:
            workload()
        rep = sess.reports[0]
        if not any(f.detector == "straggler-read-tail"
                   for f in rep.findings):
            break
    return rep


def _detectors(report):
    return sorted({f.detector for f in report.findings})


# --------------------------------------------------------------- event bus
def test_event_bus_bounded_drop_oldest():
    bus = EventBus(capacity=4)
    for i in range(10):
        bus.push(i)
    assert len(bus) == 4
    assert bus.dropped == 6
    assert bus.drain() == [6, 7, 8, 9]
    assert bus.drain() == []


# ------------------------------------------------------- e2e pathologies
def test_tiny_read_storm_triggers_only_small_file_detector(tmp_path):
    paths = []
    for i in range(64):
        p = tmp_path / f"t{i:03d}.bin"
        p.write_bytes(b"x" * 2048)
        paths.append(str(p))
    rt = reset_runtime()

    def workload():
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 1 << 20)
            os.close(fd)

    rep = _profiled(rt, workload)
    assert _detectors(rep) == ["small-file-storm"]
    f = rep.findings[0]
    assert f.severity > 0
    assert f.evidence["opens"] == 64
    assert "shard" in f.recommendation or "stage" in f.recommendation


def test_random_offset_reads_trigger_only_thrash_detector(tmp_path):
    big = tmp_path / "big.bin"
    big.write_bytes(b"z" * (8 << 20))
    offsets = [i * 65536 for i in range(64)]
    random.Random(7).shuffle(offsets)
    rt = reset_runtime()

    def workload():
        fd = os.open(str(big), os.O_RDONLY)
        for off in offsets:
            os.pread(fd, 65536, off)
        os.close(fd)

    rep = _profiled(rt, workload)
    assert _detectors(rep) == ["random-read-thrash"]
    f = rep.findings[0]
    assert f.severity > 0
    assert f.evidence["seq_read_frac"] < 0.75
    assert f.recommendation


def test_fsync_heavy_checkpoint_triggers_only_stall_detector(tmp_path):
    ckpt = tmp_path / "ckpt.bin"
    rt = reset_runtime()

    def workload():
        fd = os.open(str(ckpt), os.O_WRONLY | os.O_CREAT, 0o644)
        for _ in range(32):
            os.write(fd, b"w" * 65536)
            os.fsync(fd)
        os.close(fd)

    rep = _profiled(rt, workload)
    assert _detectors(rep) == ["checkpoint-stall"]
    f = rep.findings[0]
    assert f.severity > 0
    assert f.evidence["fsyncs"] == 32
    assert "async" in f.recommendation


def test_stat_scan_triggers_only_metadata_detector(tmp_path):
    p = tmp_path / "probe.bin"
    p.write_bytes(b"a" * 100)
    rt = reset_runtime()

    def workload():
        for _ in range(64):
            os.stat(str(p))

    rep = _profiled(rt, workload)
    assert _detectors(rep) == ["metadata-storm"]
    assert rep.findings[0].evidence["stats"] == 64


# --------------------------------------------------- findings in exports
def test_findings_flow_into_chrome_trace_and_json_report(tmp_path):
    paths = []
    for i in range(32):
        p = tmp_path / f"f{i:03d}.bin"
        p.write_bytes(b"q" * 1024)
        paths.append(str(p))
    rt = reset_runtime()

    def workload():
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 4096)
            os.close(fd)

    rep = _profiled(rt, workload)
    assert rep.findings

    trace = to_chrome_trace(rep.segments, findings=rep.findings)
    instants = [e for e in trace["traceEvents"] if e.get("ph") == "i"]
    assert len(instants) == len(rep.findings)
    assert instants[0]["pid"] == "INSIGHT"
    assert "recommendation" in instants[0]["args"]

    payload = to_json_report(rep, str(tmp_path / "r.json"))
    assert payload["insight"]["count"] == len(rep.findings)
    assert payload["insight"]["findings"][0]["detector"] \
        == rep.findings[0].detector
    assert payload["insight"]["max_severity"] > 0


# --------------------------------------------------------- hook lifecycle
def test_engine_attach_detach_does_not_leak_listener():
    rt = reset_runtime()
    eng = InsightEngine()
    assert rt.listener_count() == 0
    eng.attach(rt)
    eng.attach(rt)                       # idempotent
    # columnar runtime: poll() reads the trace ring by cursor, so no
    # bus listener is registered (the hot path stays row-free)
    assert rt.listener_count() == 0
    assert eng.attached
    eng.detach()
    eng.detach()                         # idempotent
    assert rt.listener_count() == 0
    assert not eng.attached

    # tracing disabled => the ring can't serve; the bus hook returns,
    # and detach must still not leak it
    rt2 = reset_runtime()
    rt2.trace.enabled = False
    eng2 = InsightEngine().attach(rt2)
    eng2.attach(rt2)                     # idempotent
    assert rt2.listener_count() == 1
    eng2.detach()
    eng2.detach()
    assert rt2.listener_count() == 0


def test_session_owned_engine_detaches_on_stop(tmp_path):
    rt = reset_runtime()
    sess = ProfileSession(rt, insight=True)
    sess.start()
    eng = sess.insight_engine
    assert eng.attached
    assert rt.listener_count() == 0      # columnar path: ring, no hook
    p = tmp_path / "x.bin"
    p.write_bytes(b"b" * 64)
    fd = os.open(str(p), os.O_RDONLY)
    os.read(fd, 64)
    os.close(fd)
    sess.stop()
    assert not eng.attached
    # restartable: second window re-attaches cleanly
    sess.start()
    assert eng.attached
    sess.stop()
    assert not eng.attached
    assert rt.listener_count() == 0

    # with tracing off the engine listens on the bus instead, and the
    # stop() detach must remove that hook
    sess2 = ProfileSession(rt, insight=True, trace=False)
    sess2.start()
    assert rt.listener_count() == 1
    sess2.stop()
    assert rt.listener_count() == 0


def test_restarted_session_does_not_rereport_old_findings(tmp_path):
    paths = []
    for i in range(48):
        p = tmp_path / f"r{i:03d}.bin"
        p.write_bytes(b"v" * 256)
        paths.append(str(p))
    rt = reset_runtime()
    eng = InsightEngine()
    sess = ProfileSession(rt, insight=eng)
    with sess:                                   # window 1: storm
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 1024)
            os.close(fd)
    assert "small-file-storm" in _detectors(sess.reports[0])
    with sess:                                   # window 2: quiet
        fd = os.open(paths[0], os.O_RDONLY)
        os.read(fd, 1024)
        os.close(fd)
    assert sess.reports[1].findings == []        # window 1 not re-reported


def test_poll_returns_only_first_raised_findings(tmp_path):
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    from repro.core.attach import attach, detach
    attach(rt)
    rt.enabled = True
    try:
        def storm(tag):
            for i in range(32):
                p = tmp_path / f"{tag}{i:03d}.bin"
                p.write_bytes(b"n" * 128)
                fd = os.open(str(p), os.O_RDONLY)
                os.read(fd, 512)
                os.close(fd)
        storm("a")
        first = eng.poll()
        storm("b")
        second = eng.poll()                      # same detector continues
    finally:
        rt.enabled = False
        detach()
        eng.detach()
    assert "small-file-storm" in [f.detector for f in first]
    # the continuing storm coalesces instead of repeating
    assert "small-file-storm" not in [f.detector for f in second]
    assert "small-file-storm" in [f.detector for f in eng.active_findings()]
    assert len(eng.findings_by_detector("small-file-storm")) == 1


def test_background_poller_start_stop():
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    eng.start(interval_s=0.01)
    import time
    time.sleep(0.05)
    eng.detach()                         # stops the thread too
    assert eng._bg_thread is None
    assert rt.listener_count() == 0


# ----------------------------------------------------- detector coverage
def _mk_read(path, off, length, t0, dur=1e-4):
    return Segment("POSIX", path, "read", off, length, t0, t0 + dur, 1)


def test_straggler_detector_fires_on_heavy_tail():
    det = StragglerReadTailDetector()
    segs = []
    t = 0.0
    for i in range(32):                        # same-size reads across files
        dur = 0.020 if i % 8 == 0 else 0.001   # 4 stragglers at 20ms
        segs.append(_mk_read(f"/d/f{i:02d}.bin", 0, 4096, t, dur))
        t += dur
    feats = extract(segs, 0.0, t)
    f = det.check(feats, [])
    assert f is not None and f.detector == "straggler-read-tail"
    assert f.evidence["lat_tail_ratio"] >= det.MIN_TAIL_RATIO


def test_straggler_detector_ignores_single_file_sequential_warmup():
    det = StragglerReadTailDetector()
    segs = []
    t = 0.0
    for i in range(32):                        # one file, pure sequential
        dur = 0.020 if i % 8 == 0 else 0.001
        segs.append(_mk_read("/d/f.bin", i * 4096, 4096, t, dur))
        t += dur
    assert det.check(extract(segs, 0.0, t), []) is None


def test_fast_tier_saturation_needs_sustained_peak_and_rising_tail():
    det = FastTierSaturationDetector(capacity_mb_s=100.0)

    def window(mb_s, p95):
        f = extract([], 0.0, 1.0)
        f.reads = 64
        f.read_mb_s = mb_s
        f.read_lat_p95 = p95
        return f

    history = [window(90.0, 1e-3), window(92.0, 1.2e-3)]
    cur = window(95.0, 2e-3)            # pinned at ceiling, tail x2
    f = det.check(cur, history)
    assert f is not None and f.detector == "fast-tier-saturation"
    assert 0 < f.severity <= 1
    # not sustained -> no finding
    assert det.check(cur, [window(20.0, 1e-3), window(92.0, 1.2e-3)]) is None
    # flat latency -> no finding
    assert det.check(window(95.0, 1e-3), history) is None


def test_coalescing_merges_consecutive_windows(tmp_path):
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    from repro.core.attach import attach, detach
    attach(rt)
    rt.enabled = True
    try:
        paths = []
        for i in range(96):
            p = tmp_path / f"c{i:03d}.bin"
            p.write_bytes(b"k" * 512)
            paths.append(str(p))
        for chunk in (paths[:48], paths[48:]):
            for p in chunk:
                fd = os.open(p, os.O_RDONLY)
                os.read(fd, 4096)
                os.close(fd)
            eng.poll()
    finally:
        rt.enabled = False
        detach()
        eng.detach()
    storms = eng.findings_by_detector("small-file-storm")
    assert len(storms) == 1              # two firings, one coalesced finding
    assert storms[0].window[1] > storms[0].window[0]


# ----------------------------------------------------- advisor closed loop
def _report_with_sizes(sizes):
    recs = {p: FileRecord(p, {"POSIX_READS": 1, "POSIX_OPENS": 1,
                              "POSIX_BYTES_READ": s})
            for p, s in sizes.items()}
    rep = analyze(recs, {}, elapsed_s=1.0, stat_sizes=False)
    rep.file_sizes = dict(sizes)
    return rep


def test_staging_plan_widens_threshold_on_storm_finding():
    sizes = {f"/d/f{i}": 3 * 2**20 for i in range(10)}   # 3 MiB files
    rep = _report_with_sizes(sizes)
    storm = Finding("small-file-storm", "Small-file storm", 1.0,
                    (0.0, 1.0), {}, "stage")
    adv = StagingAdvisor(size_threshold=2 * 2**20)
    assert adv.plan(rep).total_files == 0            # 3 MiB > 2 MiB cutoff
    plan = adv.plan(rep, findings=[storm])           # cutoff widened to 4 MiB
    assert plan.total_files == 10
    assert plan.size_threshold == 4 * 2**20


def test_thread_advisor_bias_from_findings():
    adv = ThreadAutotuneAdvisor(start=8)
    storm = Finding("small-file-storm", "s", 0.8, (0, 1), {}, "r")
    tail = Finding("straggler-read-tail", "t", 0.9, (0, 1), {}, "r")
    assert adv.bias_from_findings([]) is None
    up = adv.bias_from_findings([storm])
    assert up.threads == 16
    down = adv.bias_from_findings([tail])
    assert down.threads == 8
    down2 = adv.bias_from_findings([tail, storm])    # contention wins
    assert down2.threads == 4


def test_pipeline_autotune_accepts_insight_engine(tmp_path):
    from repro.data.pipeline import AUTOTUNE, Pipeline
    from repro.data.readers import posix_read_file
    paths = []
    for i in range(40):
        p = tmp_path / f"a{i:03d}.bin"
        p.write_bytes(b"m" * 1024)
        paths.append(str(p))
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    try:
        out = list(Pipeline(paths)
                   .map(posix_read_file, AUTOTUNE)
                   .with_insight(eng))
        assert len(out) == 40
    finally:
        eng.detach()
