"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes
and dtypes per the deliverable contract."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas

FLASH_CASES = [
    # B, Sq, Sk, H, KVH, D, causal, window, softcap, dtype
    (2, 128, 128, 4, 2, 64, True, 0, 0.0, jnp.float32),
    (1, 64, 64, 4, 4, 32, True, 0, 0.0, jnp.float32),
    (1, 100, 144, 4, 4, 64, True, 32, 0.0, jnp.bfloat16),   # ragged + window
    (2, 64, 256, 8, 2, 128, False, 0, 0.0, jnp.float32),    # cross attn
    (1, 128, 128, 2, 1, 64, True, 0, 30.0, jnp.float32),    # softcap
    (1, 32, 32, 4, 2, 64, True, 8, 0.0, jnp.bfloat16),      # tiny blocks
]


@pytest.mark.parametrize(
    "B,Sq,Sk,H,KVH,D,causal,window,softcap,dtype", FLASH_CASES)
def test_flash_attention_matches_oracle(B, Sq, Sk, H, KVH, D, causal,
                                        window, softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KVH, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KVH, Sk, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=64, block_k=64,
                                 interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expected.astype(jnp.float32))))
    assert err < tol, f"err={err}"


SSD_CASES = [
    # b, S, nh, P, N, chunk, dtype
    (2, 128, 4, 16, 8, 32, jnp.float32),
    (1, 256, 2, 32, 16, 64, jnp.float32),
    (1, 96, 3, 8, 4, 32, jnp.float32),       # S % chunk == 0, odd dims
    (2, 64, 4, 16, 8, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("b,S,nh,P,N,chunk,dtype", SSD_CASES)
def test_ssd_matches_oracle(b, S, nh, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, S, nh, P)).astype(dtype)
    B = (jax.random.normal(ks[1], (b, S, N)) * 0.5).astype(jnp.float32)
    C = (jax.random.normal(ks[2], (b, S, N)) * 0.5).astype(jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, S, nh)) - 1.0)
    A = -jnp.exp(jnp.zeros(nh))
    D = jnp.ones(nh)
    y, h = ops.ssd(x, B, C, dt, A, D, chunk=chunk)
    y_ref, h_ref = ref.ssd_ref(x, B, C, dt, A, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    assert float(jnp.max(jnp.abs(y - y_ref))) < tol
    assert float(jnp.max(jnp.abs(h - h_ref))) < tol


def test_model_ssd_chunked_matches_reference_scan():
    """The model-side chunked SSD (repro.models.ssm) against the oracle."""
    from repro.models.ssm import reference_scan, ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b, S, nh, P, N = 2, 128, 4, 16, 8
    x = jax.random.normal(ks[0], (b, S, nh, P))
    B = jax.random.normal(ks[1], (b, S, N)) * 0.5
    C = jax.random.normal(ks[2], (b, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, S, nh)) - 1.0)
    A = -jnp.exp(jnp.zeros(nh))
    D = jnp.ones(nh)
    y1, h1 = ssd_chunked(x, B, C, dt, A, D, chunk=32)
    y2, h2 = reference_scan(x, B, C, dt, A, D)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-3


def test_flash_xla_custom_vjp_grads_match_naive():
    from repro.models.flash import flash_attention_xla
    from repro.models.layers import naive_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, Sq, Sk, H, KVH, D = 2, 64, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KVH, D))
    v = jax.random.normal(ks[2], (B, Sk, KVH, D))
    win = jnp.float32(16.0)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_xla(q, k, v, win, True,
                                                   32, 0.0, 0)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, causal=True,
                                               window=16)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4
