"""Unit tests for the jax-darshan core: counters, runtime attachment,
session deltas, DXT tracing, exports."""
import json
import os

import pytest

from repro.core import counters as C
from repro.core import (ProfileSession, reset_runtime, to_chrome_trace,
                        to_darshan_log, to_json_report)
from repro.core.attach import attach, detach, is_attached, originals
from repro.core.records import FileRecord, delta
from repro.core.session import ProfileServer, control


def test_size_bins_match_darshan_bounds():
    assert C.size_bin(0) == 0
    assert C.size_bin(99) == 0
    assert C.size_bin(100) == 1
    assert C.size_bin(999_999) == 4
    assert C.size_bin(1_000_000) == 5
    assert C.size_bin(5_000_000_000) == 9
    assert C.read_bin_name(0) == "POSIX_SIZE_READ_0_100"


def test_attach_detach_restores_symbols():
    rt = reset_runtime()
    orig_open, orig_read = os.open, os.read
    attach(rt)
    assert is_attached()
    assert os.open is not orig_open
    detach()
    assert not is_attached()
    assert os.open is orig_open
    assert os.read is orig_read


def test_attach_is_idempotent_and_transparent(tmp_path):
    rt = reset_runtime()
    p = tmp_path / "f.bin"
    p.write_bytes(b"x" * 1000)
    attach(rt)
    attach(rt)          # double attach must not wrap twice
    rt.enabled = True
    fd = os.open(str(p), os.O_RDONLY)
    data = os.pread(fd, 4096, 0)
    os.close(fd)
    detach()
    detach()
    assert data == b"x" * 1000
    rec = rt.posix.record(str(p))
    assert rec.get("POSIX_OPENS") == 1
    assert rec.get("POSIX_BYTES_READ") == 1000


def test_counters_classify_sequential_consecutive(tmp_path):
    rt = reset_runtime()
    p = tmp_path / "f.bin"
    p.write_bytes(bytes(range(256)) * 16)       # 4096 bytes
    attach(rt)
    rt.enabled = True
    fd = os.open(str(p), os.O_RDONLY)
    os.pread(fd, 100, 0)        # first read: no predecessor
    os.pread(fd, 100, 100)      # consecutive (== prev end)
    os.pread(fd, 100, 300)      # sequential (> prev end), not consecutive
    os.pread(fd, 100, 0)        # backwards: neither
    os.close(fd)
    detach()
    rec = rt.posix.record(str(p))
    assert rec.get("POSIX_READS") == 4
    assert rec.get("POSIX_CONSEC_READS") == 1
    assert rec.get("POSIX_SEQ_READS") == 2
    assert rec.get("POSIX_MAX_BYTE_READ") == 399


def test_session_delta_isolates_window(tmp_path):
    rt = reset_runtime()
    p = tmp_path / "f.bin"
    p.write_bytes(b"y" * 500)
    attach(rt)
    rt.enabled = True
    fd = os.open(str(p), os.O_RDONLY)
    os.pread(fd, 500, 0)                        # before the session
    sess = ProfileSession(rt, auto_attach=False)
    sess.start()
    os.pread(fd, 200, 0)
    os.pread(fd, 300, 200)
    rep = sess.stop()
    os.close(fd)
    detach()
    assert rep.posix.reads == 2                 # only in-window ops
    assert rep.posix.bytes_read == 500
    assert rep.posix.opens == 0                 # open was pre-window


def test_stdio_layer_captures_buffered_writes(tmp_path):
    rt = reset_runtime()
    target = tmp_path / "out.txt"
    with ProfileSession(rt) as sess:
        with open(str(target), "w") as f:
            f.write("hello ")
            f.write("world")
            f.flush()
    rep = sess.reports[0]
    assert rep.stdio.writes == 2
    assert rep.stdio.bytes_written == 11
    assert rep.stdio.flushes >= 1


def test_exports_roundtrip(tmp_path):
    rt = reset_runtime()
    p = tmp_path / "data.bin"
    p.write_bytes(b"z" * 2048)
    with ProfileSession(rt) as sess:
        fd = os.open(str(p), os.O_RDONLY)
        os.pread(fd, 2048, 0)
        os.close(fd)
    rep = sess.reports[0]
    trace = to_chrome_trace(rep.segments, str(tmp_path / "t.json"))
    assert any(e.get("ph") == "X" for e in trace["traceEvents"])
    text = to_darshan_log(rep)
    assert "POSIX_BYTES_READ" in text and str(p) in text
    payload = to_json_report(rep, str(tmp_path / "r.json"))
    assert payload["posix"]["bytes_read"] == 2048
    loaded = json.loads((tmp_path / "r.json").read_text())
    assert loaded["posix"]["reads"] == payload["posix"]["reads"]


def test_record_delta_semantics():
    a = FileRecord("f", {"POSIX_READS": 10, "POSIX_MAX_BYTE_READ": 99},
                   {"POSIX_F_READ_TIME": 1.0})
    b = FileRecord("f", {"POSIX_READS": 25, "POSIX_MAX_BYTE_READ": 300},
                   {"POSIX_F_READ_TIME": 2.5})
    d = b.sub(a)
    assert d.get("POSIX_READS") == 15
    assert d.get("POSIX_MAX_BYTE_READ") == 300     # max, not difference
    assert abs(d.get("POSIX_F_READ_TIME") - 1.5) < 1e-9


def test_profile_server_interactive(tmp_path):
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt)
    try:
        assert control(srv.port, "start") == "ok"
        p = tmp_path / "f.bin"
        p.write_bytes(b"q" * 4000)
        fd = os.open(str(p), os.O_RDONLY)
        os.pread(fd, 4000, 0)
        os.close(fd)
        out = json.loads(control(srv.port, "stop"))
        assert out["bytes_read"] >= 4000
    finally:
        srv.close()
    assert not is_attached()


def test_excluded_prefixes_not_tracked():
    rt = reset_runtime()
    with ProfileSession(rt):
        with open("/proc/self/status") as f:
            f.read()
    assert all(not p.startswith("/proc/")
               for p in rt.posix.paths() + rt.stdio.paths())


def test_report_render_text(tmp_path):
    from repro.core.report import render, render_json
    rt = reset_runtime()
    p = tmp_path / "f.bin"
    p.write_bytes(b"m" * 150_000)
    with ProfileSession(rt) as sess:
        fd = os.open(str(p), os.O_RDONLY)
        os.pread(fd, 150_000, 0)
        os.pread(fd, 0, 150_000)      # EOF probe
        os.close(fd)
    rep = sess.reports[0]
    text = render(rep)
    assert "POSIX" in text and "SIZE_100K_1M" in text
    assert "double-read" in text      # diagnosed
    payload = to_json_report(rep)
    jtext = render_json(payload)
    assert "reads=2" in jtext
