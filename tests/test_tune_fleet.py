"""repro.tune over real fleets: the closed loop across every wire.

Covers the ISSUE acceptance routes — a tune action round-tripping
rank -> collector -> rank over loopback, tcp (including an idle-reaped
connection's at-least-once retry), and spool's one-way dry-run
degradation — plus spawn-vs-simulate audit equivalence and the
ServeEngine profiler hookup."""
import os
import time

from repro.insight.detectors import Finding
from repro.link import decode
from repro.link.transport import TcpTransport
from repro.tune import TuneApplier, TuneController, current_applier
from repro.tune.actions import decode_actions, encode_poll
from repro.tune.policies import StageHotFilesPolicy


def _small_files(root, n, size, tag="f"):
    os.makedirs(root, exist_ok=True)
    paths = []
    for i in range(n):
        p = os.path.join(root, f"{tag}{i:03d}.bin")
        with open(p, "wb") as f:
            f.write(os.urandom(size))
        paths.append(p)
    return paths


def _storm_finding(rank):
    return Finding("small-file-storm", "Small-file storm", 0.8,
                   (0.0, 1.0), {"opens": 48.0},
                   "stage the small files", rank=rank)


# -------------------------------------------------- loopback: full loop
def test_loopback_fleet_migrates_files_and_audits(tmp_path):
    """Simulated (thread/loopback) fleet: each rank's small-file storm
    streams to the collector, the stage-hot-files policy answers with a
    migrate-file action, the rank's applier stages real files onto the
    optane tier, and the applied acks land in the fleet audit log."""
    from repro.data.tiers import default_tiers
    from repro.profiler import Profiler, ProfilerOptions

    appliers = {}

    def workload(rank, io):
        ws = os.path.join(str(tmp_path), f"r{rank}")
        tm = default_tiers(ws)
        paths = _small_files(os.path.join(ws, "hdd", "imgs"), 24, 4096)
        app = current_applier()
        assert app is not None, "harness did not publish an applier"
        app.bind(tier_manager=tm, dataset=paths)
        appliers[rank] = (app, tm, paths)
        for p in paths:
            io.read_file(p)

    report = Profiler(ProfilerOptions(
        mode="fleet", nranks=2, insight=True, insight_interval_s=0.1,
        tune=True, tune_policies=("stage-hot-files",),
        tune_cooldown_s=60.0)).run(workload)

    audit = report.tune_audit
    assert audit, "no tune actions audited"
    migrates = [e for e in audit if e["action"]["kind"] == "migrate-file"]
    assert {e["action"]["rank"] for e in migrates} == {0, 1}
    for entry in migrates:
        assert entry["status"] == "acked"
        assert not entry["dry_run"]
        (ack,) = entry["acks"]
        assert ack["status"] == "applied"
        assert ack["after"]["migrated_files"] > 0
        assert ack["rank"] == entry["action"]["rank"]
    stats = report.fleet.tune_stats
    assert stats["planned"] == stats["acked"] == len(migrates) == 2

    # the knob really turned: files sit on the optane tier, resolvable
    for rank, (app, tm, paths) in appliers.items():
        assert app.stats["migrated_files"] == len(paths)
        moved = app.resolve(paths[0])
        assert moved != paths[0]
        assert moved.startswith(tm.tiers["optane"].root)
        with open(paths[0], "rb") as a, open(moved, "rb") as b:
            assert a.read() == b.read()


# ------------------------------------- tcp: idle reap => at-least-once
def test_tcp_idle_reap_retry_is_at_least_once_and_idempotent(tmp_path):
    """A tune poll over a connection the server idle-reaped succeeds
    via TcpTransport's single retry; the redelivered action is absorbed
    by the applier's seen-set and the duplicate ack by the controller —
    at-least-once delivery, idempotent loop."""
    from repro.data.tiers import default_tiers
    from repro.fleet import CollectorServer, FleetCollector

    ws = str(tmp_path)
    tm = default_tiers(ws)
    paths = _small_files(os.path.join(ws, "hdd", "imgs"), 8, 4096)

    coll = FleetCollector(detectors=[])
    controller = TuneController([StageHotFilesPolicy()],
                                cooldown_s=60.0).attach(coll)
    applier = TuneApplier(rank=0, tier_manager=tm, dataset=paths)
    controller.on_findings([_storm_finding(0)])

    with CollectorServer(coll, idle_timeout_s=0.3) as srv:
        with TcpTransport("127.0.0.1", srv.port) as t:
            # poll 1: fresh connection delivers the pending action
            msg = decode(t.send_line(encode_poll(0, [])))
            (action,) = decode_actions(msg.payload)
            assert action.kind == "migrate-file"
            first_sock = t._sock
            assert first_sock is not None

            ack = applier.apply(action)
            assert ack.status == "applied"
            assert applier.stats["migrated_files"] == len(paths)

            # let the server reap the idle connection, then poll again
            # WITHOUT the ack (a lost reply): the reused socket fails,
            # the transport retries once on a fresh connection, and the
            # still-unacked action is redelivered
            time.sleep(0.7)
            msg = decode(t.send_line(encode_poll(0, [])))
            assert t._sock is not first_sock, "no reconnect happened"
            (again,) = decode_actions(msg.payload)
            assert again.action_id == action.action_id

            # idempotency: the duplicate is skipped, nothing re-runs
            dup = applier.apply(again)
            assert dup.status == "skipped"
            assert applier.stats["migrated_files"] == len(paths)

            # poll 3 ships both acks; the controller keeps the first
            # and counts the duplicate
            msg = decode(t.send_line(
                encode_poll(0, [ack.to_dict(), dup.to_dict()])))
            assert decode_actions(msg.payload) == []

    (entry,) = controller.audit_log()
    assert entry["status"] == "acked"
    assert [a["status"] for a in entry["acks"]] == ["applied"]
    assert controller.stats["duplicate_acks"] == 1


# --------------------------------------- spool: one-way degradation
def test_spool_fleet_degrades_to_logged_dry_run(tmp_path):
    """Spool carries no replies, so no action can be delivered — the
    controller must log every plan as a self-acked dry run naming the
    limitation, never drop it silently."""
    from repro.profiler import Profiler, ProfilerOptions

    files = {r: _small_files(os.path.join(str(tmp_path), f"r{r}"),
                             24, 1024, tag=f"r{r}_") for r in range(2)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p)

    report = Profiler(ProfilerOptions(
        mode="fleet", nranks=2, transport="spool",
        spool_dir=str(tmp_path / "spool"),
        insight=True, insight_interval_s=0.1,
        tune=True, tune_policies=("stage-hot-files",),
        tune_cooldown_s=60.0)).run(workload)

    audit = report.tune_audit
    assert audit, "one-way fleet silently dropped its plans"
    for entry in audit:
        assert entry["status"] == "acked"
        assert entry["dry_run"]
        assert entry["delivered_ranks"] == []
        (ack,) = entry["acks"]
        assert ack["status"] == "dry-run"
        assert ack["detail"] == ("one-way transport: plan logged, "
                                 "not delivered")
    stats = report.fleet.tune_stats
    assert stats["planned"] == stats["acked"] == len(audit)
    assert stats["issued"] == 0


# ------------------------------------- spawn vs simulate equivalence
def _audit_signature(audit):
    """Transport-independent shape of a tune audit log."""
    return sorted((e["action"]["kind"], e["action"]["policy"],
                   e["action"]["rank"], a["status"])
                  for e in audit for a in e["acks"])


def test_spawned_fleet_audit_matches_simulated(tmp_path):
    """The same dry-run tuned workload, once on threads over loopback
    and once on real OS processes over tcp, produces the same audit
    shape: one migrate-file per rank, delivered and acked dry-run by
    that rank."""
    from repro.profiler import Profiler, ProfilerOptions

    files = {r: _small_files(os.path.join(str(tmp_path), f"r{r}"),
                             48, 1024, tag=f"r{r}_") for r in range(2)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=4096)

    def options(**kw):
        return ProfilerOptions(
            mode="fleet", nranks=2, insight=True,
            insight_interval_s=0.1, detectors=("small-file-storm",),
            fleet_detectors=(), tune=True, tune_dry_run=True,
            tune_policies=("stage-hot-files",), tune_cooldown_s=60.0,
            **kw)

    sim = Profiler(options()).run(workload)
    spawned = Profiler(options(launch="spawn",
                               transport="tcp")).run(workload)

    want = [("migrate-file", "stage-hot-files", 0, "dry-run"),
            ("migrate-file", "stage-hot-files", 1, "dry-run")]
    assert _audit_signature(sim.tune_audit) == want
    assert _audit_signature(spawned.tune_audit) == want
    # dry-run still exercises the wire: actions were DELIVERED to the
    # target rank (unlike spool's self-acked plans)
    for report in (sim, spawned):
        for entry in report.tune_audit:
            assert entry["delivered_ranks"] == [entry["action"]["rank"]]
            (ack,) = entry["acks"]
            assert ack["rank"] == entry["action"]["rank"]
            assert ack["before"] == {"files_on_fast_tier": 0}
    # real processes actually ran the spawned half
    assert os.getpid() not in {s.pid
                               for s in spawned.fleet.ranks.values()}


# ----------------------------------------------- serving fleet hookup
def test_serve_engine_runs_inside_profiler_window():
    """ServeEngine(profiler=...) wraps each serve() call in one
    profiled window; with tune=True the closed loop is armed on the
    serving path too (no I/O findings here, so the audit stays empty
    but the report exists and decoding is unchanged)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import init_params
    from repro.profiler import Profiler, ProfilerOptions
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("qwen1.5-4b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.array([3, 1, 4], np.int32)

    plain = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    want = plain.serve([Request(prompt, max_new_tokens=4)])[0].out

    prof = Profiler(ProfilerOptions(insight=True, tune=True))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                      profiler=prof)
    got = eng.serve([Request(prompt, max_new_tokens=4)])[0].out

    assert got == want
    report = prof.report
    assert report is not None and report.mode == "local"
    assert report.tune_audit == []      # no I/O storm while decoding
