"""Integration: trainer fault tolerance + learnable-data loss decrease,
monitor validation, serving consistency, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config


def _learnable_batches(vocab, batch, seq):
    """Deterministic periodic token stream — a learnable dataset."""
    rng = np.random.default_rng(0)
    pattern = rng.integers(0, vocab, 16)
    while True:
        start = rng.integers(0, 16, batch)
        rows = [np.tile(pattern, seq // 16 + 2)[s:s + seq + 1]
                for s in start]
        yield np.stack(rows).astype(np.int32)


def test_trainer_loss_decreases_and_recovers_from_failure(tmp_path):
    from repro.train.trainer import FailureInjector, Trainer, TrainerConfig
    cfg = get_config("qwen1.5-4b", reduced=True)
    tcfg = TrainerConfig(steps=24, checkpoint_every=8, log_every=4,
                         checkpoint_dir=str(tmp_path / "ck"),
                         checkpoint_async=False, microbatches=2)
    fail = FailureInjector(fail_at_step=12)
    tr = Trainer(cfg, tcfg, _learnable_batches(cfg.vocab_size, 4, 64),
                 failure=fail)
    out = tr.run()
    assert out["final_step"] == 24
    assert fail.fired
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] * 0.9, losses


def test_monitor_agrees_with_darshan_bytes(tmp_path):
    from repro.core import IOMonitor, ProfileSession, reset_runtime
    from repro.data.readers import posix_read_file
    paths = []
    for i in range(20):
        p = tmp_path / f"{i}.bin"
        p.write_bytes(os.urandom(200_000))
        paths.append(str(p))
    rt = reset_runtime()
    mon = IOMonitor(0.02).start()
    with ProfileSession(rt) as sess:
        total = sum(len(posix_read_file(p)) for p in paths)
    mon.stop()
    rep = sess.reports[0]
    assert rep.posix.bytes_read == total == 20 * 200_000
    proc_delta = mon.samples[-1].rchar - mon.samples[0].rchar
    # /proc/self/io counts everything the process read; darshan bytes
    # must be a subset but dominate (tolerate jax/pytest background I/O)
    assert proc_delta >= rep.posix.bytes_read
    assert rep.posix.bytes_read > 0.5 * proc_delta


def test_serve_engine_matches_direct_decode():
    from repro.models import decode_step, init_cache, init_params
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen1.5-4b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.array([3, 1, 4], np.int32)

    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    out = eng.serve([Request(prompt, max_new_tokens=4)])[0].out

    # direct greedy decode, batch 1
    cache = init_cache(cfg, 1, 32)
    pos = jnp.zeros((1,), jnp.int32)
    toks = []
    cur = prompt
    nxt = None
    for t in cur:
        logits, cache = decode_step(params, cfg, cache,
                                    jnp.asarray([[t]], jnp.int32), pos)
        pos = pos + 1
        nxt = int(jnp.argmax(logits, -1)[0])
    toks.append(nxt)
    for _ in range(3):
        logits, cache = decode_step(params, cfg, cache,
                                    jnp.asarray([[toks[-1]]], jnp.int32),
                                    pos)
        pos = pos + 1
        toks.append(int(jnp.argmax(logits, -1)[0]))
    assert out == toks


def test_int8_compression_roundtrip_error_bounded():
    from repro.distributed.compression import Int8Compressor
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 3.0
    comp = Int8Compressor()
    out = comp.roundtrip_leaf(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(out - g))) <= scale * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    from repro.distributed.compression import (ErrorFeedbackCompressor,
                                               Int8Compressor)
    ef = ErrorFeedbackCompressor(Int8Compressor())
    params = {"w": jnp.zeros((64,))}
    err = ef.init_state(params)
    # a tiny constant gradient is below quantization resolution of a
    # large-dynamic-range tensor; error feedback must accumulate it
    base = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 10.0
    tiny = {"w": base * 0 + 0.01}
    sent_total = jnp.zeros((64,))
    for _ in range(50):
        sent, err = ef.compress(tiny, err)
        sent_total = sent_total + sent["w"]
    # average transmitted signal converges to the true gradient
    assert float(jnp.mean(sent_total / 50)) == pytest.approx(0.01, rel=0.2)


def test_train_step_microbatch_equivalence():
    """k microbatches must give (near-)identical grads to full batch."""
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import make_train_step
    from repro.models import init_params
    cfg = get_config("qwen1.5-4b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    ocfg = OptimizerConfig(name="adamw", lr=1e-2, warmup_steps=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.train.optimizer import init_opt_state
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab_size)}
    outs = {}
    for k in (1, 4):
        step = make_train_step(cfg, ocfg, microbatches=k)
        p, o, m = jax.jit(step)(params, init_opt_state(ocfg, params), batch)
        outs[k] = (p, m)
    p1, p4 = outs[1][0], outs[4][0]
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4))]
    assert max(diffs) < 5e-3, max(diffs)
