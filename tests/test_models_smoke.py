"""Per-architecture smoke tests: a REDUCED config of each assigned family
runs one forward/train step on CPU; output shapes + finiteness asserted.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.layers import unembed_matrix

ARCHS = list_archs()


def make_batch(cfg, key, B=2, S=32, extra=0):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S + extra), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["audio_frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    h, aux, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    B, S = batch["tokens"].shape
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_and_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, cfg, b), has_aux=True)(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), path


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(S) + decode(token S) must equal full forward at position S.

    MoE archs use a no-drop capacity factor: capacity-based token dropping
    legitimately differs between prefill-group and full-batch routing."""
    cfg = get_config(arch, reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    if cfg.moe.n_experts:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, jax.random.PRNGKey(1), B=B, S=S, extra=1)
    full_tokens = batch["tokens"]
    batch_prefill = dict(batch, tokens=full_tokens[:, :S])
    batch_full = dict(batch)

    cache, _, pos = prefill(params, cfg, batch_prefill, pad_to=S + 8)
    logits, cache = decode_step(params, cfg, cache, full_tokens[:, S:S + 1], pos)

    h, _, _ = forward(params, cfg, batch_full)
    ref = jnp.einsum("bd,dv->bv", h[:, -1, :],
                     unembed_matrix(params["embedding"], cfg))
    rel = float(jnp.max(jnp.abs(logits - ref))) / max(
        float(jnp.max(jnp.abs(ref))), 1e-6)
    assert rel < 2e-3, f"{arch}: rel={rel}"


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode_runs(arch):
    """Three chained decode steps from a zero cache produce finite logits."""
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, max_len = 2, 32
    cache = init_cache(cfg, B, max_len)
    if cfg.family == "vlm":
        # decode against precomputed (here random) cross-attention KV
        cache["cross_k"] = jax.random.normal(
            jax.random.PRNGKey(7), cache["cross_k"].shape).astype(
                cache["cross_k"].dtype)
        cache["cross_v"] = jax.random.normal(
            jax.random.PRNGKey(8), cache["cross_v"].shape).astype(
                cache["cross_v"].dtype)
    pos = jnp.zeros((B,), jnp.int32)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    for i in range(3):
        logits, cache = step(cache, tok, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        pos = pos + 1


def test_moe_capacity_drops_are_reported():
    cfg = get_config("dbrx-132b", reduced=True)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    _, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert float(metrics["moe_dropped_frac"]) > 0.0


def test_gemma3_local_global_flags():
    from repro.models.transformer import is_global_flags
    cfg = get_config("gemma3-12b")
    flags = is_global_flags(cfg, cfg.n_layers)
    # 5 local : 1 global -> every 6th layer is global
    assert int(flags.sum()) == cfg.n_layers // 6
    assert bool(flags[5]) and not bool(flags[0])
