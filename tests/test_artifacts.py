"""Guard tests over the committed experiment artifacts: every dry-run
cell must be ok, the cell matrix must cover every assigned architecture
x applicable shape on both meshes, and per-device memory must respect
the HBM budget (grok-1-314b single-pod is the one documented waiver,
EXPERIMENTS.md §Perf)."""
import glob
import json
import os

import pytest

from repro.configs import get_config, list_archs, shapes_for

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(DRYRUN, "*.json")),
    reason="dry-run artifacts not generated")

HBM = 16 * 2**30
# Documented single-pod waivers (EXPERIMENTS.md §Dry-run notes): these
# cells fit on the 512-chip multi-pod production mesh; on 256 chips the
# >100B configs and the 32k KV caches exceed one v5e's HBM (residual
# non-aliased cache copy on this CPU backend adds ~1x cache).
WAIVERS = {
    ("grok-1-314b", "train_4k", "single"),
    ("grok-1-314b", "prefill_32k", "single"),
    ("grok-1-314b", "decode_32k", "single"),
    ("dbrx-132b", "prefill_32k", "single"),
    ("gemma3-12b", "decode_32k", "single"),
    ("llama-3.2-vision-90b", "decode_32k", "single"),
    ("qwen1.5-4b", "decode_32k", "single"),
}


def _cells():
    out = {}
    for path in glob.glob(os.path.join(DRYRUN, "*.json")):
        rec = json.load(open(path))
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def test_every_assigned_cell_compiled():
    cells = _cells()
    missing = []
    for arch in list_archs():
        for shape in shapes_for(get_config(arch)):
            for mesh in ("single", "multi"):
                key = (arch, shape.name, mesh)
                if key not in cells or not cells[key].get("ok"):
                    missing.append(key)
    assert not missing, missing


def test_long_500k_runs_exactly_for_subquadratic_archs():
    cells = _cells()
    ran = {a for (a, s, m) in cells if s == "long_500k"}
    expected = {a for a in list_archs()
                if get_config(a).supports_long_context}
    assert ran == expected


def test_per_device_memory_within_budget():
    over = []
    for key, rec in _cells().items():
        if not rec.get("ok"):
            continue
        mem = rec["memory"]["per_device_total"]
        if mem > HBM and key not in WAIVERS:
            over.append((key, round(mem / 2**30, 2)))
    assert not over, over


def test_multi_pod_uses_512_devices():
    for key, rec in _cells().items():
        expected = 512 if key[2] == "multi" else 256
        assert rec["n_devices"] == expected, key
