import os
import sys

# Make `repro` importable without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set --xla_force_host_platform_device_count here.  Smoke tests
# and benches must see ONE device; multi-device tests run in subprocesses
# (see tests/test_dryrun_mini.py) where the flag is set before jax imports.

import jax

jax.config.update("jax_enable_x64", False)
