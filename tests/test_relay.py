"""repro.relay: binary column frames, relay tiers, collection trees,
backpressure/drop accounting, and the authenticated/TLS transport."""
import os
import shutil
import socket
import subprocess
import time

import numpy as np
import pytest

from repro.core.runtime import DarshanRuntime
from repro.fleet.collector import CollectorServer, FleetCollector
from repro.fleet.harness import RankIO, simulate_fleet
from repro.fleet.launch import run_spawned_fleet
from repro.fleet.reporter import RankReporter
from repro.link import (AuthError, LoopbackTransport, TcpTransport,
                        WireError, check_auth, encode, encode_auth)
from repro.relay import (RelayNode, RelayServer, RelayServerTree, RelayTree,
                         SpoolRelayTree, TreeSpec, decode_frame,
                         encode_frame, is_frame, plan_tree)
from repro.trace import SegmentColumns

SECRET = "test-relay-secret"


def _columns(n=64, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    t = 0.0
    for i in range(n):
        t += float(rng.uniform(1e-5, 1e-3))
        length = int(rng.choice([4096, 65536, 1 << 20]))
        rows.append(("POSIX", f"/data/shard_{i % 7:03d}.bin", "read",
                     int(i) * 4096, length, t,
                     t + float(rng.uniform(1e-5, 1e-3)), i % 4))
    from repro.core.dxt import Segment
    return SegmentColumns.from_rows([Segment(*r) for r in rows])


def _workload(paths):
    def wl(rank, io):
        fd = io.open(paths[rank % len(paths)])
        for _ in range(4):
            io.pread(fd, 65536, 0)
        io.close(fd)
    return wl


@pytest.fixture(scope="module")
def data_file(tmp_path_factory):
    p = tmp_path_factory.mktemp("relay") / "data.bin"
    p.write_bytes(os.urandom(1 << 20))
    return str(p)


# ============================================================ frame codec
class TestFrames:
    def test_roundtrip_payload_and_batch(self):
        cols = _columns(100)
        payload = {"nprocs": 4, "elapsed_s": 1.5,
                   "clock": {"offset_s": 0.25},
                   "segments_columns": cols}
        msg = decode_frame(encode_frame("report", 3, payload))
        assert msg.kind == "report" and msg.rank == 3
        assert msg.payload["nprocs"] == 4
        got = msg.payload["segments_columns"]
        assert isinstance(got, SegmentColumns)
        assert len(got) == len(cols)
        for a, b in zip(got, cols):
            assert a == b

    def test_roundtrip_uncompressed(self):
        cols = _columns(10)
        frame = encode_frame("report", 0, {"segments_columns": cols},
                             compress=False)
        got = decode_frame(frame).payload["segments_columns"]
        assert list(got) == list(cols)

    def test_roundtrip_empty_batch(self):
        empty = SegmentColumns.from_rows([])
        msg = decode_frame(encode_frame("report", 0,
                                        {"segments_columns": empty}))
        assert len(msg.payload["segments_columns"]) == 0

    def test_nested_batches(self):
        a, b = _columns(5, seed=1), _columns(9, seed=2)
        payload = {"reports": [{"rank": 0, "segments_columns": a},
                               {"rank": 1, "segments_columns": b}]}
        msg = decode_frame(encode_frame("relay_report", 0, payload))
        got = msg.payload["reports"]
        assert len(got[0]["segments_columns"]) == 5
        assert len(got[1]["segments_columns"]) == 9

    def test_is_frame_vs_json_line(self):
        frame = encode_frame("report", 0, {})
        assert is_frame(frame)
        assert not is_frame(encode("report", 0, {}).encode())
        # the sniffing invariant: a frame can never start a JSON line
        assert frame[:1] not in (b"{", b"[")

    def test_float_times_bit_exact(self):
        # XOR-delta on the f64 bit patterns must be exactly reversible,
        # including awkward values
        from repro.core.dxt import Segment
        cols = SegmentColumns.from_rows([
            Segment("POSIX", "/a", "read", 0, 1, 1e-308, 0.1, 0),
            Segment("POSIX", "/a", "read", 1, 1, 0.1, float(np.pi), 0),
            Segment("POSIX", "/a", "read", 2, 1, 1e300, 1e300, 0)])
        got = decode_frame(
            encode_frame("report", 0,
                         {"segments_columns": cols})).payload[
                             "segments_columns"]
        assert got.start.tobytes() == cols.start.tobytes()
        assert got.end.tobytes() == cols.end.tobytes()

    def test_corruption_detected(self):
        frame = bytearray(encode_frame("report", 1,
                                       {"segments_columns": _columns(32)}))
        frame[len(frame) // 2] ^= 0xFF
        with pytest.raises(WireError):
            decode_frame(bytes(frame))

    def test_truncation_detected(self):
        frame = encode_frame("report", 1, {"segments_columns": _columns(32)})
        for cut in (0, 3, 10, len(frame) - 1):
            with pytest.raises(WireError):
                decode_frame(frame[:cut])

    def test_bad_magic_and_version(self):
        frame = bytearray(encode_frame("report", 0, {}))
        bad = b"XXXX" + bytes(frame[4:])
        with pytest.raises(WireError):
            decode_frame(bad)
        frame[4] = 99                      # version byte
        with pytest.raises(WireError):
            decode_frame(bytes(frame))

    def test_trailing_garbage_rejected(self):
        frame = encode_frame("report", 0, {"segments_columns": _columns(4)})
        with pytest.raises(WireError):
            decode_frame(frame + b"extra")

    def test_fuzz_every_truncation_point(self):
        # deterministic twin of the hypothesis fuzz (which skips when
        # hypothesis is absent): EVERY prefix must raise WireError —
        # never a struct/zlib/numpy error, never a partial decode
        frame = encode_frame("report", 0, {"segments_columns": _columns(16)})
        for cut in range(len(frame)):
            with pytest.raises(WireError):
                decode_frame(frame[:cut])

    def test_fuzz_random_bit_flips(self):
        rng = np.random.default_rng(1234)
        frame = encode_frame("report", 0, {"segments_columns": _columns(16)})
        for _ in range(300):
            buf = bytearray(frame)
            pos = int(rng.integers(0, len(buf)))
            buf[pos] ^= 1 << int(rng.integers(0, 8))
            try:
                decode_frame(bytes(buf))
            except WireError:
                pass          # detected — the only acceptable failure


# =============================================================== topology
class TestTopology:
    def test_plan_fanout_only(self):
        spec = plan_tree(1000, fanout=32)
        assert spec.tiers == (32,)
        spec = plan_tree(1000, fanout=8)
        assert spec.tiers == (2, 16, 125)

    def test_plan_depth_only(self):
        spec = plan_tree(1000, depth=2)
        assert spec.depth == 2
        assert spec.tiers[-1] * spec.fanout >= 1000

    def test_plan_both(self):
        spec = plan_tree(64, fanout=4, depth=2)
        assert spec.tiers == (4, 16)

    def test_plan_flat(self):
        assert plan_tree(10).tiers == ()

    def test_plan_errors(self):
        with pytest.raises(ValueError):
            plan_tree(0, fanout=4)
        with pytest.raises(ValueError):
            plan_tree(10, fanout=1)
        with pytest.raises(ValueError):
            plan_tree(10, fanout=4, depth=0)

    def test_leaf_assignment_balanced(self):
        spec = plan_tree(100, fanout=10)
        counts = {}
        for r in range(100):
            leaf = spec.leaf_of(r)
            assert 0 <= leaf < spec.tiers[-1]
            counts[leaf] = counts.get(leaf, 0) + 1
        assert max(counts.values()) - min(counts.values()) <= 1
        # contiguous blocks: leaf id is monotone in rank
        leaves = [spec.leaf_of(r) for r in range(100)]
        assert leaves == sorted(leaves)

    def test_parent_bounds(self):
        spec = plan_tree(1000, fanout=8)
        for t in range(1, spec.depth):
            for i in range(spec.tiers[t]):
                assert 0 <= spec.parent_of(t, i) < spec.tiers[t - 1]

    def test_spec_is_plain_data(self):
        spec = plan_tree(64, fanout=4)
        assert spec == TreeSpec(nranks=64, fanout=4, tiers=(4, 16))


# ========================================================== relay merging
class TestRelayNode:
    def _ship_rank(self, rank, target, data_file, nprocs=2):
        rt = DarshanRuntime(dxt_capacity=4096)
        io = RankIO(rt)
        rep = RankReporter(rank, nprocs=nprocs, runtime=rt,
                           auto_attach=False)
        rep.start()
        fd = io.open(data_file)
        io.pread(fd, 65536, 0)
        io.close(fd)
        rep.stop()
        t = LoopbackTransport(target)
        rep.ship(t)
        t.close()
        return rep

    def test_relay_merges_and_forwards(self, data_file):
        coll = FleetCollector()
        relay = RelayNode(upstream=LoopbackTransport(coll), name="r0",
                          flush_interval_s=0.02)
        relay.start()
        for r in range(3):
            self._ship_rank(r, relay, data_file, nprocs=3)
        relay.close()
        fr = coll.report()
        assert sorted(fr.ranks) == [0, 1, 2]
        assert all(s.posix.reads == 1 for s in fr.ranks.values())
        assert fr.relay["relays"]["r0"]["reports_in"] == 3
        assert fr.relay["dropped_reports"] == 0
        # relay hello must not create a phantom rank slice
        assert set(fr.ranks) == {0, 1, 2}

    def test_clock_alignment_composes(self, data_file):
        # a rank with a skewed clock through a relay must land on the
        # collector clock just like a flat fleet would
        skew = 5.0
        coll = FleetCollector()
        relay = RelayNode(upstream=LoopbackTransport(coll), name="r0",
                          flush_interval_s=0.02)
        relay.start()
        rt = DarshanRuntime(dxt_capacity=4096)
        rt._t0 -= skew                     # rank clock reads 5s ahead
        io = RankIO(rt)
        rep = RankReporter(0, nprocs=1, runtime=rt, auto_attach=False)
        rep.start()
        fd = io.open(data_file)
        io.pread(fd, 4096, 0)
        io.close(fd)
        rep.stop()
        t = LoopbackTransport(relay)
        rep.ship(t)
        t.close()
        relay.close()
        fr = coll.report()
        seg = next(iter(fr.ranks[0].segments))
        # collector clock is ~0 at test start: an unaligned segment
        # would sit at ~+5s
        assert abs(seg.start) < 2.0

    def test_busy_when_queue_full(self):
        relay = RelayNode(upstream=None, name="r0", max_pending=1,
                          flush_interval_s=60)
        line = encode("report", 0, {"nprocs": 1, "elapsed_s": 0.1,
                                    "posix": {}, "segments": [],
                                    "clock": {}})
        reply = relay.ingest_line(line)
        assert '"kind":"ok"' in reply.replace(" ", "") or reply == "ok"
        reply = relay.ingest_line(line.replace('"rank":0', '"rank":1'))
        assert "busy" in reply
        assert relay.stats["busy_replies"] == 1
        assert "retry_after_s" in reply

    def test_reporter_busy_retry_exhaustion(self, data_file):
        relay = RelayNode(upstream=None, name="r0", max_pending=0,
                          flush_interval_s=0.01)
        rt = DarshanRuntime(dxt_capacity=4096)
        rep = RankReporter(0, nprocs=1, runtime=rt, auto_attach=False)
        rep.start()
        rep.stop()
        t = LoopbackTransport(relay)
        with pytest.raises(RuntimeError, match="busy"):
            rep.ship(t, busy_retries=3)

    def test_close_accounts_unflushed(self, data_file):
        # no upstream: close() cannot flush — pending must be counted,
        # never silently discarded
        relay = RelayNode(upstream=None, name="r0", flush_interval_s=60)
        self._ship_rank(0, relay, data_file, nprocs=1)
        relay.close()
        assert relay.stats["dropped_reports"] == 1

    def test_findings_stream_through(self):
        coll = FleetCollector()
        relay = RelayNode(upstream=LoopbackTransport(coll), name="r0",
                          flush_interval_s=0.02)
        relay.start()
        line = encode("findings", 2, {
            "findings": [{"detector": "d", "title": "t", "severity": 0.5,
                          "window": [0.0, 1.0], "evidence": {},
                          "recommendation": "r"}],
            "streaming": True})
        relay.ingest_line(line)
        relay.close()
        assert relay.stats["findings_forwarded"] == 1
        assert coll.stats["findings"] == 1

    def test_corrupt_frame_counted(self):
        relay = RelayNode(upstream=None, name="r0")
        with pytest.raises(WireError):
            relay.ingest_frame(b"RFR1garbage")


# ====================================================== trees over wires
class TestTrees:
    def test_flat_vs_tree_equivalence(self, data_file):
        wl = _workload([data_file])
        flat, tree = FleetCollector(), FleetCollector()
        fr_flat = simulate_fleet(8, wl, flat, dxt_capacity=4096)
        fr_tree = simulate_fleet(8, wl, tree, relay_fanout=3,
                                 dxt_capacity=4096)
        assert sorted(fr_tree.ranks) == sorted(fr_flat.ranks)
        assert fr_tree.posix.reads == fr_flat.posix.reads
        assert fr_tree.posix.bytes_read == fr_flat.posix.bytes_read
        for r in fr_flat.ranks:
            assert (len(fr_tree.ranks[r].segments_table())
                    == len(fr_flat.ranks[r].segments_table()))
        assert fr_tree.relay["dropped_reports"] == 0
        assert fr_flat.relay == {}

    def test_deep_tree_loopback(self, data_file):
        coll = FleetCollector()
        fr = simulate_fleet(12, _workload([data_file]), coll,
                            relay_fanout=2, relay_depth=2,
                            dxt_capacity=4096)
        assert sorted(fr.ranks) == list(range(12))
        assert fr.relay["dropped_reports"] == 0
        # depth 2: both tiers show up in the rollup stats
        names = set(fr.relay["relays"])
        assert any(n.startswith("relay-t0") for n in names)
        assert any(n.startswith("relay-t1") for n in names)

    def test_relay_with_make_transport_conflict(self, data_file):
        with pytest.raises(ValueError, match="make_transport"):
            simulate_fleet(2, _workload([data_file]), FleetCollector(),
                           relay_fanout=2,
                           make_transport=lambda r: None)

    def test_server_tree_tcp(self, data_file):
        coll = FleetCollector()
        csrv = CollectorServer(coll)
        tree = RelayServerTree.build("127.0.0.1", csrv.port,
                                     plan_tree(4, fanout=2),
                                     flush_interval_s=0.02)
        try:
            fr = simulate_fleet(
                4, _workload([data_file]), coll, collect=False,
                dxt_capacity=4096,
                make_transport=lambda r: TcpTransport(
                    "127.0.0.1", tree.port_for(r)))
        finally:
            tree.close()
            csrv.close()
        fr = coll.report()
        assert sorted(fr.ranks) == list(range(4))
        assert fr.relay["dropped_reports"] == 0

    def test_spawned_tcp_tree(self, data_file):
        coll = FleetCollector()
        fr = run_spawned_fleet(4, _workload([data_file]), coll,
                               transport="tcp", relay_fanout=2,
                               dxt_capacity=4096, timeout_s=60)
        assert sorted(fr.ranks) == list(range(4))
        assert all(s.posix.reads == 4 for s in fr.ranks.values())
        assert fr.relay["dropped_reports"] == 0

    def test_spawned_spool_tree(self, data_file):
        coll = FleetCollector()
        fr = run_spawned_fleet(4, _workload([data_file]), coll,
                               transport="spool", relay_fanout=2,
                               dxt_capacity=4096, timeout_s=60)
        assert sorted(fr.ranks) == list(range(4))
        assert fr.relay["dropped_reports"] == 0

    def test_spool_auth_rejected(self, data_file):
        with pytest.raises(ValueError, match="tcp"):
            run_spawned_fleet(2, _workload([data_file]), FleetCollector(),
                              transport="spool", auth_secret="nope")


# ===================================================== mixed-version fleet
class TestMixedFleet:
    def test_binary_and_json_ranks_coexist(self, data_file):
        """Half the fleet ships binary frames (columns wire), half ships
        legacy JSON rows through the SAME relay — the collector must see
        an identical picture for both."""
        coll = FleetCollector()
        relay = RelayNode(upstream=LoopbackTransport(coll), name="r0",
                          flush_interval_s=0.02)
        relay.start()
        for rank in range(4):
            rt = DarshanRuntime(dxt_capacity=4096)
            io = RankIO(rt)
            rep = RankReporter(rank, nprocs=4, runtime=rt,
                               auto_attach=False,
                               segments_wire=("columns" if rank % 2 == 0
                                              else "rows"))
            rep.start()
            fd = io.open(data_file)
            io.pread(fd, 65536, 0)
            io.close(fd)
            rep.stop()
            t = LoopbackTransport(relay)
            rep.ship(t)
            t.close()
        relay.close()
        assert relay.stats["frames_in"] == 2      # the columns ranks
        fr = coll.report()
        assert sorted(fr.ranks) == [0, 1, 2, 3]
        sizes = {len(s.segments_table()) for s in fr.ranks.values()}
        assert len(sizes) == 1                    # identical windows
        reads = {s.posix.reads for s in fr.ranks.values()}
        assert reads == {1}


# ================================================================== auth
class TestAuth:
    def test_auth_codec_roundtrip(self):
        line = encode_auth(SECRET, rank=7)
        check_auth(SECRET, __import__("json").loads(line)["payload"])

    def test_auth_rejects_bad_mac_and_stale(self):
        import json
        payload = json.loads(encode_auth(SECRET))["payload"]
        with pytest.raises(AuthError):
            check_auth("other-secret", payload)
        stale = dict(payload, ts=payload["ts"] - 10_000)
        with pytest.raises(AuthError):
            check_auth(SECRET, stale)

    def test_tcp_auth_accept_reject(self, data_file):
        coll = FleetCollector()
        srv = CollectorServer(coll, auth_secret=SECRET)
        try:
            rt = DarshanRuntime(dxt_capacity=4096)
            io = RankIO(rt)
            rep = RankReporter(0, nprocs=1, runtime=rt, auto_attach=False)
            rep.start()
            fd = io.open(data_file)
            io.pread(fd, 4096, 0)
            io.close(fd)
            rep.stop()
            good = TcpTransport("127.0.0.1", srv.port, auth_secret=SECRET)
            rep.ship(good)
            good.close()
            bad = TcpTransport("127.0.0.1", srv.port,
                               auth_secret="wrong-secret")
            with pytest.raises(AuthError) as ei:
                bad.send_line(encode("hello", 0, {"nprocs": 1,
                                                  "link_v": 1}))
            assert "wrong-secret" not in str(ei.value)  # never leak it
            bad.close()
            # a client that never authenticates gets an error reply and
            # a dropped connection — its hello must not be ingested
            hellos_before = coll.stats["hellos"]
            unauth = TcpTransport("127.0.0.1", srv.port)
            reply = unauth.send_line(encode("hello", 9, {"nprocs": 1,
                                                         "link_v": 1}))
            assert reply is None or reply.startswith("error")
            unauth.close()
            assert coll.stats["hellos"] == hellos_before
            assert 9 not in coll.ranks
        finally:
            srv.close()
        assert 0 in coll.ranks

    def test_reconnect_reauthenticates(self):
        coll = FleetCollector()
        srv = CollectorServer(coll, auth_secret=SECRET, idle_timeout_s=0.2)
        try:
            t = TcpTransport("127.0.0.1", srv.port, auth_secret=SECRET,
                             timeout=5.0)
            t.send_line(encode("hello", 0, {"nprocs": 1, "link_v": 1}))
            time.sleep(0.6)                # idle reaper kills the conn
            t.send_line(encode("clock", 0, {"t_send": 0.0}))
            assert t.stats["auths"] >= 2   # re-auth on reconnect
            t.close()
        finally:
            srv.close()

    def test_relay_server_requires_auth(self):
        rs = RelayServer(node=RelayNode(upstream=None, name="r0"),
                         auth_secret=SECRET)
        try:
            bad = TcpTransport("127.0.0.1", rs.port, auth_secret="nope")
            with pytest.raises(AuthError):
                bad.send_line(encode("clock", 0, {"t_send": 0.0}))
            bad.close()
            good = TcpTransport("127.0.0.1", rs.port, auth_secret=SECRET)
            reply = good.send_line(encode("clock", 0, {"t_send": 0.0}))
            assert "clock_reply" in reply
            good.close()
        finally:
            rs.close()


# =================================================================== tls
def _have_openssl():
    return shutil.which("openssl") is not None


@pytest.fixture(scope="module")
def tls_cert(tmp_path_factory):
    if not _have_openssl():
        pytest.skip("openssl CLI not available")
    d = tmp_path_factory.mktemp("tls")
    cert, key = str(d / "cert.pem"), str(d / "key.pem")
    subprocess.run(
        ["openssl", "req", "-x509", "-newkey", "rsa:2048", "-keyout", key,
         "-out", cert, "-days", "2", "-nodes", "-subj", "/CN=repro-relay"],
        check=True, capture_output=True)
    return cert, key


class TestTls:
    def test_tls_auth_report_ships(self, data_file, tls_cert):
        cert, key = tls_cert
        coll = FleetCollector()
        srv = CollectorServer(coll, auth_secret=SECRET, ssl_certfile=cert,
                              ssl_keyfile=key)
        try:
            rt = DarshanRuntime(dxt_capacity=4096)
            io = RankIO(rt)
            rep = RankReporter(0, nprocs=1, runtime=rt, auto_attach=False)
            rep.start()
            fd = io.open(data_file)
            io.pread(fd, 4096, 0)
            io.close(fd)
            rep.stop()
            t = TcpTransport("127.0.0.1", srv.port, auth_secret=SECRET,
                             tls_ca=cert)
            rep.ship(t)
            t.close()
        finally:
            srv.close()
        assert 0 in coll.ranks
        assert coll.ranks[0].posix.reads == 1

    def test_plaintext_client_rejected_by_tls_server(self, tls_cert):
        cert, key = tls_cert
        coll = FleetCollector()
        srv = CollectorServer(coll, ssl_certfile=cert, ssl_keyfile=key)
        try:
            t = TcpTransport("127.0.0.1", srv.port, timeout=2.0)
            with pytest.raises(OSError):
                t.send_line(encode("clock", 0, {"t_send": 0.0}))
            t.close()
        finally:
            srv.close()

    def test_spawned_fleet_tls_tree(self, data_file, tls_cert):
        cert, key = tls_cert
        coll = FleetCollector()
        fr = run_spawned_fleet(
            4, _workload([data_file]), coll, transport="tcp",
            relay_fanout=2, dxt_capacity=4096, auth_secret=SECRET,
            tls_certfile=cert, tls_keyfile=key, tls_ca=cert, timeout_s=90)
        assert sorted(fr.ranks) == list(range(4))
        assert fr.relay["dropped_reports"] == 0


# ============================================================== report API
def test_fleet_report_relay_in_dict(data_file):
    coll = FleetCollector()
    fr = simulate_fleet(2, _workload([data_file]), coll, relay_fanout=2,
                        dxt_capacity=4096)
    d = fr.to_dict()
    assert d["relay"]["dropped_reports"] == 0
    assert "relays" in d["relay"]


def test_health_summary_flags_relay_drops():
    from repro.obs.metrics import health_summary
    snap = {"counters": {"relay.dropped_reports": 2}}
    h = health_summary(snap)
    assert h["checks"]["relay-drops"]["status"] == "degraded"
    assert h["status"] == "degraded"
    ok = health_summary({"counters": {}})
    assert ok["checks"]["relay-drops"]["status"] == "ok"
