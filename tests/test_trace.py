"""The columnar trace data plane (repro.trace): ring wraparound,
interning, window queries, the DXTBuffer compatibility view, the
vectorized feature extraction, and the listener-error surfacing that
rides on the new runtime emit path."""
import dataclasses
import os
import threading

import pytest

from repro.core import ProfileSession, reset_runtime
from repro.insight.features import extract, extract_columns, extract_rows
from repro.trace import SEG_DTYPE, Segment, SegmentColumns, TraceStore


def _seg(i, path="/d/a.bin", op="read", length=4096):
    return Segment("POSIX", path, op, i * length, length,
                   float(i), i + 0.5, 7)


# ------------------------------------------------------------ ring store
def test_ring_keeps_everything_under_capacity():
    st = TraceStore(capacity=16)
    for i in range(10):
        st.add(_seg(i))
    assert len(st) == 10
    assert st.dropped == 0
    assert st.snapshot().to_rows() == [_seg(i) for i in range(10)]


def test_ring_wraparound_drops_oldest_and_counts():
    st = TraceStore(capacity=16)
    for i in range(40):
        st.add(_seg(i))
    assert len(st) == 16
    assert st.dropped == 24
    rows = st.snapshot().to_rows()
    # exactly the newest 16, oldest -> newest
    assert rows == [_seg(i) for i in range(24, 40)]


def test_ring_wraparound_at_exact_capacity_boundary():
    st = TraceStore(capacity=8)
    for i in range(8):
        st.add(_seg(i))
    assert st.dropped == 0
    st.add(_seg(8))
    assert st.dropped == 1
    assert st.snapshot().to_rows() == [_seg(i) for i in range(1, 9)]


def test_interning_tables_shared_across_rows():
    st = TraceStore(capacity=64)
    for i in range(30):
        st.append("POSIX", f"/d/f{i % 3}", ("read", "write")[i % 2],
                  0, 10, float(i), i + 0.1, 1)
    cols = st.snapshot()
    assert set(cols.paths) == {"/d/f0", "/d/f1", "/d/f2"}
    assert set(cols.ops) == {"read", "write"}
    assert cols.modules == ("POSIX",)
    # ids stay within table bounds after wraparound too
    assert int(cols.path_ids.max()) < len(cols.paths)


def test_window_queries_match_row_filter():
    st = TraceStore(capacity=128)
    for i in range(50):
        st.add(_seg(i))
    assert st.window(10.0, 19.0).to_rows() == \
        [_seg(i) for i in range(10, 20)]
    assert st.window_rows(45.0) == [_seg(i) for i in range(45, 50)]
    assert len(st.window(1e9)) == 0


def test_since_cursor_and_overrun_accounting():
    st = TraceStore(capacity=8)
    for i in range(4):
        st.add(_seg(i))
    cols, cur, dropped = st.since(0)
    assert (len(cols), cur, dropped) == (4, 4, 0)
    for i in range(4, 20):           # overruns the ring by 4 past cursor
        st.add(_seg(i))
    cols, cur2, dropped = st.since(cur)
    assert cur2 == 20
    assert dropped == 8              # rows 4..11 were overwritten
    assert cols.to_rows() == [_seg(i) for i in range(12, 20)]
    # a stale (pre-clear) cursor clamps instead of exploding
    st.clear()
    cols, cur3, dropped = st.since(cur2)
    assert (len(cols), cur3, dropped) == (0, 0, 0)


def test_disabled_store_records_nothing():
    st = TraceStore(capacity=8, enabled=False)
    st.add(_seg(0))
    assert len(st) == 0
    st.enabled = True
    st.add(_seg(1))
    assert len(st) == 1


def test_concurrent_append_and_window_never_tear():
    """The satellite fix: a window scan concurrent with wrapping
    appends must observe only fully written rows."""
    st = TraceStore(capacity=256)
    stop = threading.Event()
    bad = []

    def writer():
        i = 0
        while not stop.is_set():
            st.append("POSIX", f"/d/f{i % 7}", "read", i, 64,
                      float(i), float(i) + 0.25, 1)
            i += 1

    def scanner():
        while not stop.is_set():
            for seg in st.snapshot():
                # end - start is always exactly 0.25 in this stream; a
                # torn row would pair a start with another row's end
                if abs((seg.end - seg.start) - 0.25) > 1e-9:
                    bad.append(seg)

    threads = [threading.Thread(target=writer) for _ in range(2)] + \
        [threading.Thread(target=scanner) for _ in range(2)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not bad


# ------------------------------------------------------- columnar batches
def test_columns_row_surface():
    rows = [_seg(i, path=f"/d/{i % 2}", op=("read", "open")[i % 2])
            for i in range(9)]
    cols = SegmentColumns.from_rows(rows)
    assert len(cols) == 9
    assert list(cols) == rows
    assert cols[0] == rows[0]
    assert cols[-1] == rows[-1]
    assert cols[2:5].to_rows() == rows[2:5]
    with pytest.raises(IndexError):
        cols[9]
    assert SegmentColumns.empty().to_rows() == []


def test_columns_shift_sort_and_slice():
    rows = [_seg(i) for i in (3, 1, 2)]
    cols = SegmentColumns.from_rows(rows)
    shifted = cols.shift_time(10.0)
    assert [s.start for s in shifted] == [13.0, 11.0, 12.0]
    assert [s.end - s.start for s in shifted] == \
        [s.end - s.start for s in cols]
    assert [s.start for s in cols.sorted_by_start()] == [1.0, 2.0, 3.0]
    assert cols.time_slice(2.0).to_rows() == [_seg(3), _seg(2)]
    # shift by zero is the identity (and shares the data)
    assert cols.shift_time(0.0) is cols


def test_columns_concat_reinterns():
    a = SegmentColumns.from_rows([_seg(0, path="/p/a"), _seg(1, "/p/b")])
    b = SegmentColumns.from_rows([_seg(2, path="/p/b"), _seg(3, "/p/c")])
    cat = SegmentColumns.concat([a, b])
    assert cat.to_rows() == a.to_rows() + b.to_rows()
    assert set(cat.paths) == {"/p/a", "/p/b", "/p/c"}


def test_columns_wire_roundtrip_through_json():
    import json
    rows = [Segment("STDIO", "/log/x", "write", 5, 11, 0.25, 0.5, 42),
            Segment("POSIX", "/d/y", "read", 0, 1 << 30, 1e-7, 2e-7, 9)]
    cols = SegmentColumns.from_rows(rows)
    wire = json.loads(json.dumps(cols.to_wire()))
    assert SegmentColumns.from_wire(wire).to_rows() == rows


def test_seg_dtype_is_stable_layout():
    # the wire and the ring share this layout; renames/reorders are a
    # protocol change and must be deliberate
    assert SEG_DTYPE.names == ("module", "path", "op", "offset",
                               "length", "start", "end", "thread")


# ------------------------------------------------ dxt compatibility view
def test_dxtbuffer_view_shares_runtime_store():
    rt = reset_runtime()
    assert rt.dxt.store is rt.trace
    rt.enabled = True
    rt.posix_open(3, "/d/z.bin", 0.0, 0.1)
    rt.posix_read(3, 0, 100, 0.2, 0.3, advance=False)
    assert len(rt.dxt) == len(rt.trace) == 2
    segs = rt.dxt.window(0.0)
    assert [s.op for s in segs] == ["open", "read"]
    assert rt.dxt.columns(0.0).to_rows() == segs
    # t1 alone still slices (upper bound only)
    assert rt.dxt.columns(t1=0.15).to_rows() == segs[:1]
    rt.dxt.clear()
    assert len(rt.trace) == 0


def test_dxtbuffer_enabled_toggles_store():
    from repro.core.dxt import DXTBuffer
    buf = DXTBuffer(capacity=8)
    buf.enabled = False
    buf.add(_seg(0))
    assert len(buf) == 0
    buf.enabled = True
    buf.add(_seg(1))
    assert len(buf) == 1 and buf.store.enabled


# -------------------------------------------------- vectorized extraction
def _mixed_stream(n=600, files=7):
    segs = []
    t = 0.0
    for i in range(n):
        op = ("read", "read", "read", "write", "open", "stat", "seek",
              "flush", "fsync")[i % 9]
        length = (0, 512, 4096, 1 << 20)[i % 4] \
            if op in ("read", "write") else 0
        dur = (1e-5, 3e-4, 2e-3)[i % 3]
        segs.append(Segment("POSIX", f"/d/f{(i * 5) % files}", op,
                            (i % 11) * 4096, length, t, t + dur, 1))
        t += dur * 0.6
    return segs, t


def test_extract_columns_matches_row_loop():
    segs, t1 = _mixed_stream()
    cols = SegmentColumns.from_rows(segs)
    a = extract_rows(segs, 0.0, t1, zero_reads=5, monitor_read_mb_s=3.5)
    b = extract_columns(cols, 0.0, t1, zero_reads=5,
                        monitor_read_mb_s=3.5)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, float) or isinstance(vb, float):
            assert vb == pytest.approx(va, rel=1e-9, abs=1e-12), f.name
        else:
            assert va == vb, f.name


def test_extract_dispatches_on_input_shape():
    segs, t1 = _mixed_stream(90)
    cols = SegmentColumns.from_rows(segs)
    assert extract(cols, 0.0, t1).reads == extract(segs, 0.0, t1).reads
    assert extract(SegmentColumns.empty(), 0.0, 1.0).data_ops == 0


def test_engine_poll_uses_columnar_window(tmp_path):
    """The engine reads the runtime's trace ring directly; detectors
    see the same storm either way."""
    from repro.insight import InsightEngine
    paths = []
    for i in range(48):
        p = tmp_path / f"s{i:03d}.bin"
        p.write_bytes(b"x" * 256)
        paths.append(str(p))
    rt = reset_runtime()
    eng = InsightEngine()
    sess = ProfileSession(rt, insight=eng, insight_interval_s=60.0)
    with sess:
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 1024)
            os.close(fd)
    rep = sess.reports[0]
    assert "small-file-storm" in {f.detector for f in rep.findings}
    # the columnar cursor advanced past everything it analyzed
    assert eng._seq == rt.trace.seq


# ------------------------------------------------- listener error surface
def test_listener_errors_counted_and_on_report(tmp_path):
    rt = reset_runtime()

    def broken_listener(seg):
        raise ValueError("detector bug")

    def fine_listener(seg):
        pass

    rt.add_segment_listener(broken_listener)
    rt.add_segment_listener(fine_listener)
    sess = ProfileSession(rt)
    p = tmp_path / "x.bin"
    p.write_bytes(b"y" * 128)
    with sess:
        fd = os.open(str(p), os.O_RDONLY)
        os.read(fd, 128)
        os.close(fd)
    rep = sess.reports[0]
    assert len(rep.segments) >= 2
    key = next(iter(rep.listener_errors))
    assert "broken_listener" in key
    assert rep.listener_errors[key] == len(rep.segments)
    assert len(rep.listener_errors) == 1      # the healthy one is absent
    # a second, clean window starts from zero again
    with sess:
        pass
    assert sess.reports[1].listener_errors == {}


def test_listener_errors_reach_profiler_report(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    rt = reset_runtime()

    def bad(seg):
        raise RuntimeError("boom")

    rt.add_segment_listener(bad)
    p = tmp_path / "w.bin"
    p.write_bytes(b"z" * 64)

    def workload():
        fd = os.open(str(p), os.O_RDONLY)
        os.read(fd, 64)
        os.close(fd)

    report = Profiler(ProfilerOptions(), runtime=rt).run(workload)
    assert sum(report.listener_errors.values()) >= 2
    assert "listener_errors" in report.to_dict()


# ------------------------------------------------------ report table view
def test_profiler_report_segments_table(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    rt = reset_runtime()
    p = tmp_path / "t.bin"
    p.write_bytes(b"k" * 4096)

    def workload():
        fd = os.open(str(p), os.O_RDONLY)
        os.read(fd, 4096)
        os.close(fd)

    report = Profiler(ProfilerOptions(), runtime=rt).run(workload)
    table = report.segments_table()
    assert isinstance(table, SegmentColumns)
    assert table.to_rows() == report.segments
    assert int(table.op_mask("read").sum()) == report.posix.reads


# ----------------------------------------------- wire validation + size
def test_from_wire_rejects_malformed_payloads():
    rows = [_seg(i, path=f"/p/{i}") for i in range(3)]
    good = SegmentColumns.from_rows(rows).to_wire()

    import copy
    out_of_range = copy.deepcopy(good)
    out_of_range["op"][1] = 7                 # no such op id
    with pytest.raises(ValueError):
        SegmentColumns.from_wire(out_of_range)

    negative = copy.deepcopy(good)
    negative["path"][0] = -1                  # would alias the last path
    with pytest.raises(ValueError):
        SegmentColumns.from_wire(negative)

    ragged = copy.deepcopy(good)
    ragged["offset"] = ragged["offset"][:1]   # would broadcast silently
    with pytest.raises(ValueError):
        SegmentColumns.from_wire(ragged)

    from repro.link import WireError
    from repro.fleet import payloads
    with pytest.raises(WireError):
        payloads.decode_segments_columns(out_of_range)


def test_to_wire_ships_only_referenced_strings():
    """A window sliced from a long-lived store must not drag the
    store's whole interning history over the wire."""
    st = TraceStore(capacity=4)
    for i in range(500):                      # 500 distinct paths seen
        st.append("POSIX", f"/d/f{i:04d}", "read", 0, 64,
                  float(i), i + 0.5, 1)
    cols = st.snapshot()
    wire = cols.to_wire()
    assert len(wire["tables"]["path"]) == 4   # only the live rows' paths
    assert SegmentColumns.from_wire(wire).to_rows() == cols.to_rows()
    compacted = cols.compact()
    assert compacted.to_rows() == cols.to_rows()
    assert set(compacted.paths) == {s.path for s in cols}


def test_store_compacts_interning_and_clear_resets_tables():
    st = TraceStore(capacity=8)
    for i in range(1000):
        st.append("POSIX", f"/d/f{i:05d}", "read", 0, 64,
                  float(i), i + 0.5, 1)
    # the table is bounded (compaction evicts dead strings), not the
    # full 1000-path history
    assert len(st._paths) <= 300
    assert st.snapshot().to_rows() == \
        [Segment("POSIX", f"/d/f{i:05d}", "read", 0, 64, float(i),
                 i + 0.5, 1) for i in range(992, 1000)]
    st.clear()
    assert st._paths == {} and st._ops == {}
    assert len(st.snapshot().paths) == 0


def test_columnar_engine_path_materializes_no_rows(tmp_path):
    """With an attached engine on a columnar runtime the hot path
    registers no listener, so _emit never constructs Segment rows."""
    from repro.insight import InsightEngine
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    try:
        assert rt.listener_count() == 0
        assert len(eng.bus) == 0
        rt.enabled = True
        rt.posix_open(5, "/d/q.bin", 0.0, 0.1)
        rt.posix_read(5, 0, 128, 0.2, 0.3, advance=False)
        assert len(eng.bus) == 0              # nothing rode the bus
        eng.poll()
        assert eng.history[-1].reads == 1     # yet the window saw it
    finally:
        rt.enabled = False
        eng.detach()


def test_session_report_rows_are_lazy(tmp_path):
    rt = reset_runtime()
    p = tmp_path / "lz.bin"
    p.write_bytes(b"m" * 1024)
    sess = ProfileSession(rt)
    with sess:
        fd = os.open(str(p), os.O_RDONLY)
        os.read(fd, 1024)
        os.close(fd)
    rep = sess.reports[0]
    assert rep._segments_rows is None         # nothing materialized yet
    rows = rep.segments
    assert rows and rep._segments_rows is rows
    assert rows == rep.segments_columns.to_rows()
    # explicit assignment (synthetic reports) still wins
    rep.segments = rows[:1]
    assert rep.segments == rows[:1]


def test_decode_segments_columns_wraps_overflow():
    """numpy raises OverflowError (not ValueError) for out-of-dtype
    values; one corrupt line must stay a WireError so spool drains
    survive it."""
    from repro.fleet import payloads
    from repro.link import WireError
    good = SegmentColumns.from_rows([_seg(0)]).to_wire()
    import copy
    huge = copy.deepcopy(good)
    huge["offset"] = [2 ** 70]
    with pytest.raises(WireError):
        payloads.decode_segments_columns(huge)
    negative_thread = copy.deepcopy(good)
    negative_thread["thread"] = [-1]
    with pytest.raises(WireError):
        payloads.decode_segments_columns(negative_thread)


def test_segments_setter_invalidates_stale_columns():
    """Assigned rows are the authority: the wire must ship them, not a
    stale columnar batch from before the assignment."""
    from repro.core.analysis import analyze
    from repro.fleet import payloads
    from repro.link.messages import decode
    rep = analyze({}, {}, elapsed_s=1.0, stat_sizes=False)
    rep.file_sizes = {}
    rep.segments_columns = SegmentColumns.from_rows(
        [_seg(i) for i in range(5)])
    rep.segments = [_seg(99)]              # caller overrides the window
    assert rep.segments_columns is None
    msg = decode(payloads.encode_report(0, rep))
    shipped = payloads.decode_report_segments(msg.payload).to_rows()
    assert shipped == [_seg(99)]


def test_reporter_downgrades_wire_for_legacy_collector():
    """A collector that answers hello with a bare ack (or a typed hello
    without the segments_columns cap) predates the columnar wire; the
    reporter must ship rows it can decode."""
    from repro.core.analysis import analyze
    from repro.core.runtime import DarshanRuntime
    from repro.fleet.reporter import RankReporter
    from repro.link.messages import decode

    def synth():
        rep = analyze({}, {}, elapsed_s=1.0, stat_sizes=False)
        rep.file_sizes = {}
        rep.segments = [_seg(0)]
        return rep

    from repro.link.messages import encode as _encode

    def make_legacy(shipped, hello_reply):
        def legacy_collector(line):
            shipped.append(line)
            msg = decode(line)
            if msg.kind == "clock":       # legacy peers did speak clock
                return _encode("clock_reply", msg.rank, {"t_coll": 0.0})
            if msg.kind == "hello":
                return hello_reply
            return "ok"
        return legacy_collector

    # case 1: bare-ack hello (pre-typed-hello peer)
    # case 2: typed hello without the caps field (PR-4-era collector)
    for hello_reply in ("ok", _encode("hello", 0, {"link_v": 1})):
        shipped = []
        r = RankReporter(0, runtime=DarshanRuntime(), auto_attach=False)
        assert r.effective_segments_wire == "columns"
        r.ship(make_legacy(shipped, hello_reply), report=synth())
        assert r.effective_segments_wire == "rows"
        report_lines = [ln for ln in shipped
                        if decode(ln).kind == "report"]
        assert len(report_lines) == 1
        payload = decode(report_lines[0]).payload
        assert "segments" in payload
        assert "segments_columns" not in payload

    # a modern collector advertises the cap, so columns ride the wire
    from repro.fleet import FleetCollector
    coll = FleetCollector()
    r2 = RankReporter(1, runtime=DarshanRuntime(), auto_attach=False)
    r2.ship(coll.ingest_line, report=synth())
    assert r2.effective_segments_wire == "columns"
    s = coll.report().ranks[1]
    seg = s.segments[0]                    # clock-aligned by the offset
    assert (seg.module, seg.path, seg.op, seg.offset, seg.length) \
        == ("POSIX", "/d/a.bin", "read", 0, 4096)
    assert seg.start - s.clock_offset_s == pytest.approx(0.0, abs=1e-9)


def test_engine_follows_trace_flag_flips(tmp_path):
    """A nested session constructed with trace=False disables the
    runtime's ring; an attached engine must fall back to the bus hook
    instead of going silently blind (and return to the ring when the
    flag comes back)."""
    from repro.insight import InsightEngine
    rt = reset_runtime()
    eng = InsightEngine().attach(rt)
    rt.enabled = True
    try:
        assert rt.listener_count() == 0      # columnar path
        rt.trace.enabled = False             # nested trace=False session
        eng.poll()                           # notices, hooks the bus
        assert rt.listener_count() == 1
        rt.posix_open(9, "/d/n.bin", 0.0, 0.1)
        rt.posix_read(9, 0, 256, 0.2, 0.3, advance=False)
        eng.poll()
        assert eng.history[-1].reads == 1    # still seeing segments
        rt.trace.enabled = True              # tracing restored
        eng.poll()                           # switches back to the ring
        assert rt.listener_count() == 0
        rt.posix_read(9, 256, 256, 0.4, 0.5, advance=False)
        eng.poll()
        assert eng.history[-1].reads == 1
    finally:
        rt.enabled = False
        eng.detach()
