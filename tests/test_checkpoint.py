"""Checkpoint fault-tolerance tests: atomicity, CRC, keep-N, async,
structure-preserving restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import MANIFEST, CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (8, 16)),
                      "b": jnp.zeros((16,))},
            "step_count": jnp.ones((), jnp.int32) * 7}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save(5, tree, extra={"note": "hi"})
    restored, extra = mgr.restore(target_tree=tree)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staging_dir_never_visible_as_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    # simulate a crash mid-save: staging dir exists, no commit rename
    stage = tmp_path / "step_0000000009.staging"
    stage.mkdir()
    (stage / "junk.npy").write_bytes(b"partial")
    assert mgr.latest_step() is None
    mgr.save(10, _tree())
    assert mgr.latest_step() == 10


def test_corruption_detected_by_crc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    ckpt = tmp_path / "step_0000000001"
    victim = next(f for f in os.listdir(ckpt) if f.endswith(".npy"))
    path = ckpt / victim
    data = bytearray(path.read_bytes())
    data[-1] ^= 0xFF
    path.write_bytes(bytes(data))
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(1, target_tree=_tree())


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones((4,))})
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_completes_and_surfaces_errors(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ok"), keep=2)
    tree = _tree()
    mgr.save_async(3, tree)
    mgr.wait()
    assert mgr.latest_step() == 3
    restored, _ = mgr.restore(target_tree=tree)
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(tree["layer"]["w"]))


def test_restore_latest_picks_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (10, 20, 15):
        mgr.save(s, {"x": jnp.full((2,), s, jnp.float32)})
    restored, _ = mgr.restore(target_tree={"x": jnp.zeros((2,))})
    assert float(restored["x"][0]) == 20.0


def test_manifest_is_json_with_shapes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(2, _tree())
    manifest = json.loads(
        (tmp_path / "step_0000000002" / MANIFEST).read_text())
    names = {e["name"] for e in manifest["entries"]}
    assert "layer/w" in names and "step_count" in names
    e = next(e for e in manifest["entries"] if e["name"] == "layer/w")
    assert e["shape"] == [8, 16]
