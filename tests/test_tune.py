"""repro.tune unit surface: actions wire codec, policies, controller
(cooldown / dry-run / one-way degradation), applier (idempotency,
migration, thread resize via PipelineControl, checkpoint throttle),
registry integration, options validation, and the local closed loop
through the Profiler façade."""
import os

import pytest

from repro.insight.detectors import Finding
from repro.link import WireError
from repro.link.messages import decode, encode
from repro.tune import (ACTION_KINDS, TUNE_VERSION, LocalTuneLoop,
                        TuneAck, TuneAction, TuneApplier, TuneController,
                        current_applier, make_builtin_policy,
                        set_current_applier)
from repro.tune.actions import (decode_acks, decode_actions,
                                encode_actions, encode_poll)


def finding(detector="small-file-storm", rank=None, severity=0.8):
    return Finding(detector=detector, title=detector, severity=severity,
                   window=(0.0, 1.0), evidence={}, recommendation="",
                   rank=rank)


def make_controller(dry_run=False, cooldown_s=0.0, policies=None):
    if policies is None:
        policies = [make_builtin_policy("stage-hot-files")]
    return TuneController(policies, dry_run=dry_run, cooldown_s=cooldown_s)


# ---------------------------------------------------------------- actions
class TestActionWire:
    def test_round_trip(self):
        a = TuneAction(action_id="a0001", kind="migrate-file",
                       params={"tier": "optane"}, policy="stage-hot-files",
                       reason="storm", rank=2, issued_at=1.5)
        b = TuneAction.from_dict(a.to_dict())
        assert b == a
        assert b.v == TUNE_VERSION

    def test_unknown_kind_rejected(self):
        with pytest.raises(WireError):
            TuneAction.from_dict({"action_id": "x", "kind": "reboot",
                                  "params": {}, "v": TUNE_VERSION})

    def test_newer_version_rejected(self):
        d = TuneAction(action_id="a1", kind="resize-threads",
                       params={}).to_dict()
        d["v"] = TUNE_VERSION + 1
        with pytest.raises(WireError):
            TuneAction.from_dict(d)

    def test_ack_round_trip(self):
        ack = TuneAck("a1", 3, "applied", before={"threads": 4},
                      after={"threads": 8}, detail="ok")
        assert TuneAck.from_dict(ack.to_dict()) == ack

    def test_poll_and_actions_messages(self):
        line = encode_poll(1, [TuneAck("a1", 1, "applied").to_dict()])
        msg = decode(line)
        assert msg.kind == "tune" and msg.payload["poll"]
        acks = decode_acks(msg.payload)
        assert acks[0].action_id == "a1"
        reply = encode_actions(
            1, [TuneAction(action_id="a2", kind="resize-threads",
                           params={"direction": "up"})], dry_run=True)
        actions = decode_actions(reply.payload)
        assert actions[0].kind == "resize-threads"
        assert reply.payload["dry_run"] is True

    def test_tune_verb_registered(self):
        # the verb rides the shared plugin registry like any extension
        from repro.profiler import registry
        assert "tune" in registry.get_registry("verb")
        # the codec accepts the kind end to end
        decode(encode("tune", 0, {"poll": True, "acks": []}))


# ---------------------------------------------------------------- policies
class TestPolicies:
    def test_stage_hot_files_plans_migration(self):
        actions = make_builtin_policy("stage-hot-files").plan(
            finding("small-file-storm", rank=1))
        assert len(actions) == 1
        a = actions[0]
        assert a.kind == "migrate-file" and a.rank == 1
        assert a.params["tier"] == "optane"

    def test_autotune_threads_direction(self):
        pol = make_builtin_policy("autotune-threads")
        up = pol.plan(finding("small-file-storm"))
        assert up[0].params["direction"] == "up"
        down = pol.plan(finding("straggler-read-tail"))
        assert down[0].params["direction"] == "down"

    def test_checkpoint_backoff_scales_with_severity(self):
        pol = make_builtin_policy("checkpoint-backoff")
        low = pol.plan(finding("checkpoint-stall", severity=0.3))
        high = pol.plan(finding("checkpoint-stall", severity=1.0))
        assert high[0].params["min_interval_s"] \
            > low[0].params["min_interval_s"]

    def test_unrelated_finding_plans_nothing(self):
        for name in ("stage-hot-files", "autotune-threads",
                     "checkpoint-backoff"):
            assert make_builtin_policy(name).plan(
                finding("random-read-thrash")) == []

    def test_unknown_policy_name(self):
        with pytest.raises(ValueError):
            make_builtin_policy("defragment-the-moon")

    def test_registry_create(self):
        from repro.profiler import registry
        pol = registry.create("policy", "stage-hot-files", None)
        assert pol.plan(finding())[0].kind == "migrate-file"


# -------------------------------------------------------------- controller
class TestController:
    def test_plan_issue_ack_lifecycle(self):
        ctrl = make_controller()
        planned = ctrl.on_findings([finding(rank=0)])
        assert len(planned) == 1
        assert ctrl.entries[0].status == "planned"
        actions = ctrl.poll_actions(0)
        assert [a.action_id for a in actions] == [planned[0].action_id]
        assert ctrl.entries[0].status == "issued"
        assert ctrl.record_ack(TuneAck(planned[0].action_id, 0, "applied"))
        assert ctrl.entries[0].status == "acked"
        assert ctrl.poll_actions(0) == []      # acked: no redelivery

    def test_redelivers_until_acked(self):
        ctrl = make_controller()
        ctrl.on_findings([finding(rank=0)])
        first = ctrl.poll_actions(0)
        again = ctrl.poll_actions(0)           # lost reply heals
        assert [a.action_id for a in first] \
            == [a.action_id for a in again]

    def test_targeted_delivery(self):
        ctrl = make_controller()
        ctrl.on_findings([finding(rank=1)])
        assert ctrl.poll_actions(0) == []      # targeted at rank 1
        assert len(ctrl.poll_actions(1)) == 1

    def test_duplicate_acks_counted_once(self):
        ctrl = make_controller()
        aid = ctrl.on_findings([finding(rank=0)])[0].action_id
        ctrl.poll_actions(0)
        assert ctrl.record_ack(TuneAck(aid, 0, "applied"))
        assert not ctrl.record_ack(TuneAck(aid, 0, "applied"))
        assert ctrl.stats["duplicate_acks"] == 1
        assert ctrl.stats["acked"] == 1

    def test_cooldown_suppresses_repeat_plans(self):
        ctrl = make_controller(cooldown_s=60.0)
        assert len(ctrl.on_findings([finding(rank=0)])) == 1
        assert ctrl.on_findings([finding(rank=0)]) == []
        assert ctrl.stats["cooldown_suppressed"] == 1

    def test_one_way_self_acks_dry_run(self):
        ctrl = make_controller()
        ctrl.mark_one_way()
        ctrl.on_findings([finding(rank=0)])
        entry = ctrl.entries[0]
        assert entry.status == "acked" and entry.dry_run
        assert entry.acks[0].status == "dry-run"
        assert "one-way" in entry.acks[0].detail
        assert ctrl.poll_actions(0) == []      # nothing deliverable

    def test_handle_poll_round_trip(self):
        ctrl = make_controller(dry_run=True)
        ctrl.on_findings([finding(rank=0)])
        msg = decode(encode_poll(0, []))
        reply = ctrl.handle_poll(msg)
        assert reply.payload["dry_run"] is True
        assert len(reply.payload["actions"]) == 1

    def test_broken_policy_is_contained(self):
        class Boom:
            name = "boom"

            def plan(self, finding):
                raise RuntimeError("boom")

        ctrl = TuneController(
            [Boom(), make_builtin_policy("stage-hot-files")],
            cooldown_s=0.0)
        assert len(ctrl.on_findings([finding(rank=0)])) == 1


# ----------------------------------------------------------------- applier
class TestApplier:
    def test_duplicate_delivery_skipped(self):
        app = TuneApplier(rank=0)
        a = TuneAction(action_id="a1", kind="resize-threads",
                       params={"threads": 4})
        first = app.apply(a)
        again = app.apply(a)
        assert first.status == "rejected"      # no control bound
        assert again.status == "skipped"
        assert again.detail == "duplicate delivery"

    def test_dry_run_snapshots_and_changes_nothing(self):
        from repro.data.pipeline import PipelineControl
        control = PipelineControl(threads=4)
        app = TuneApplier(rank=0, pipeline_control=control)
        ack = app.apply(TuneAction(action_id="a1", kind="resize-threads",
                                   params={"threads": 8}), dry_run=True)
        assert ack.status == "dry-run"
        assert ack.before == {"threads": 4}
        assert control.take_request() is None

    def test_resize_directive_scales_current(self):
        from repro.data.pipeline import PipelineControl
        control = PipelineControl(threads=4)
        app = TuneApplier(rank=0, pipeline_control=control)
        ack = app.apply(TuneAction(
            action_id="a1", kind="resize-threads",
            params={"direction": "up", "factor": 2}))
        assert ack.status == "applied" and ack.after["threads"] == 8
        assert control.take_request() == 8
        ack = app.apply(TuneAction(
            action_id="a2", kind="resize-threads",
            params={"direction": "down", "factor": 16}))
        assert ack.after["threads"] == 1       # clamped at >= 1

    def test_migrate_stages_small_files(self, tmp_path):
        from repro.data.synthetic import make_imagenet_like
        from repro.data.tiers import default_tiers
        tm = default_tiers(str(tmp_path))
        paths = make_imagenet_like(str(tmp_path / "hdd" / "d"),
                                   n_files=6, seed=1)
        app = TuneApplier(rank=0, tier_manager=tm, dataset=paths)
        ack = app.apply(TuneAction(
            action_id="a1", kind="migrate-file",
            params={"tier": "optane", "size_threshold": 2 << 20}))
        assert ack.status == "applied"
        assert ack.after["migrated_files"] == 6
        for p in paths:
            dst = app.resolve(p)
            assert dst != p and tm.tier_of(dst).name == "optane"
            with open(p, "rb") as a, open(dst, "rb") as b:
                assert a.read() == b.read()
        # re-issue: already-migrated files are not copied again
        ack2 = app.apply(TuneAction(
            action_id="a2", kind="migrate-file",
            params={"tier": "optane", "size_threshold": 2 << 20}))
        assert ack2.after["migrated_files"] == 0

    def test_migrate_without_bindings_rejected(self):
        ack = TuneApplier(rank=0).apply(TuneAction(
            action_id="a1", kind="migrate-file", params={}))
        assert ack.status == "rejected"

    def test_throttle_checkpoint(self, tmp_path):
        from repro.train.checkpoint import CheckpointManager
        ckpt = CheckpointManager(str(tmp_path / "ck"))
        app = TuneApplier(rank=0, checkpoint_manager=ckpt)
        ack = app.apply(TuneAction(
            action_id="a1", kind="throttle-checkpoint",
            params={"min_interval_s": 3.5}))
        assert ack.status == "applied"
        assert ckpt.min_interval_s == 3.5

    def test_failure_becomes_failed_ack(self):
        class BadControl:
            @property
            def current_threads(self):
                raise RuntimeError("boom")

        app = TuneApplier(rank=0, pipeline_control=BadControl())
        ack = app.apply(TuneAction(action_id="a1", kind="resize-threads",
                                   params={"direction": "up"}))
        assert ack.status == "failed" and "boom" in ack.detail

    def test_bind_rejects_unknown_knob(self):
        with pytest.raises(ValueError):
            TuneApplier(rank=0).bind(gpu_clock=3.0)

    def test_current_applier_publication(self):
        app = TuneApplier(rank=0)
        set_current_applier(app)
        try:
            assert current_applier() is app
        finally:
            set_current_applier(None)
        assert current_applier() is None


# --------------------------------------------------- checkpoint throttling
class TestCheckpointThrottle:
    def test_async_saves_spaced(self, tmp_path):
        from repro.train.checkpoint import CheckpointManager
        ckpt = CheckpointManager(str(tmp_path / "ck"), keep=10)
        tree = {"w": __import__("numpy").zeros((4,))}
        assert ckpt.save_async(1, tree)
        ckpt.wait()
        prev = ckpt.set_throttle(60.0)
        assert prev == 0.0
        assert not ckpt.save_async(2, tree)    # inside the interval
        assert ckpt.throttle_skipped == 1
        ckpt.set_throttle(0.0)
        assert ckpt.save_async(3, tree)        # throttle off again
        ckpt.wait()
        assert ckpt.latest_step() == 3

    def test_sync_save_never_throttled(self, tmp_path):
        from repro.train.checkpoint import CheckpointManager
        ckpt = CheckpointManager(str(tmp_path / "ck"), keep=10)
        tree = {"w": __import__("numpy").zeros((2,))}
        ckpt.set_throttle(60.0)
        ckpt.save(1, tree)
        ckpt.save(2, tree)                     # the final save must land
        assert ckpt.latest_step() == 2


# ------------------------------------------------------- pipeline control
class TestPipelineControl:
    def test_autotune_honors_external_request(self):
        from repro.data.pipeline import AUTOTUNE, Pipeline, PipelineControl
        control = PipelineControl()
        seen = []

        def fn(i):
            seen.append(control.current_threads)
            return b"x" * 64

        control.request_threads(7)
        pipe = (Pipeline(list(range(160)))
                .map(fn, AUTOTUNE)
                .with_control(control))
        list(pipe)
        # the request lands at a window boundary: the second window
        # runs with exactly the requested count (the climb continues
        # from there afterwards)
        assert 7 in seen

    def test_take_request_is_once(self):
        from repro.data.pipeline import PipelineControl
        c = PipelineControl()
        c.request_threads(3)
        assert c.take_request() == 3
        assert c.take_request() is None


# ------------------------------------------------------------- local loop
class TestLocalLoop:
    def test_facade_closed_loop_migrates(self, tmp_path):
        from repro.core import reset_runtime
        from repro.data.synthetic import make_imagenet_like
        from repro.data.tiers import default_tiers, make_tiered_reader
        from repro.profiler import Profiler, ProfilerOptions
        tm = default_tiers(str(tmp_path))
        paths = make_imagenet_like(str(tmp_path / "hdd" / "d"),
                                   n_files=24, seed=2)
        prof = Profiler(ProfilerOptions(insight=True, tune=True),
                        runtime=reset_runtime())
        with prof:
            assert prof.bind_tune(dataset=paths, tier_manager=tm)
            reader = make_tiered_reader(
                tm, resolver=prof.tune_applier.resolve)
            for p in paths:
                reader(p)
            applied = prof.tune_tick()
        assert applied >= 1
        assert prof.tune_applier.stats["migrated_files"] == 24
        audit = prof.report.tune_audit
        assert any(e["status"] == "acked"
                   and e["action"]["kind"] == "migrate-file"
                   for e in audit)
        assert "tune_audit" in prof.report.to_dict()

    def test_bind_tune_noop_when_off(self):
        from repro.profiler import Profiler
        prof = Profiler()
        assert prof.bind_tune(dataset=[]) is False
        assert prof.tune_tick() == 0

    def test_loop_tick_applies_and_acks(self):
        class FakeEngine:
            def __init__(self):
                self.findings = []

            def poll(self):
                return []

        engine = FakeEngine()
        ctrl = make_controller()
        app = TuneApplier(rank=0)
        loop = LocalTuneLoop(engine, ctrl, app, rank=0)
        assert loop.tick() == 0
        engine.findings.append(finding(rank=0))
        assert loop.tick() == 1
        assert ctrl.entries[0].status == "acked"
        assert loop.tick() == 0                # acked: nothing pending


# ----------------------------------------------------------------- options
class TestOptions:
    def test_tune_requires_insight(self):
        from repro.profiler import ProfilerOptions
        from repro.profiler.options import ProfilerOptionsError
        with pytest.raises(ProfilerOptionsError):
            ProfilerOptions(tune=True).validate()

    def test_tune_knobs_require_tune(self):
        from repro.profiler import ProfilerOptions
        from repro.profiler.options import ProfilerOptionsError
        with pytest.raises(ProfilerOptionsError):
            ProfilerOptions(tune_policies=("stage-hot-files",)).validate()
        with pytest.raises(ProfilerOptionsError):
            ProfilerOptions(tune_dry_run=True).validate()

    def test_unknown_policy_fails_fast(self):
        from repro.profiler import Profiler, ProfilerOptions, registry
        with pytest.raises(registry.RegistryError):
            Profiler(ProfilerOptions(insight=True, tune=True,
                                     tune_policies=("nope",)))

    def test_intervals_validated(self):
        from repro.profiler import ProfilerOptions
        from repro.profiler.options import ProfilerOptionsError
        with pytest.raises(ProfilerOptionsError):
            ProfilerOptions(insight=True, tune=True,
                            tune_cooldown_s=-1.0).validate()
        with pytest.raises(ProfilerOptionsError):
            ProfilerOptions(insight=True, tune=True,
                            tune_interval_s=0.0).validate()

    def test_register_policy_decorator(self):
        from repro.profiler import register_policy, registry

        @register_policy("test-noop-policy", override=True)
        def make(opts):
            class Noop:
                name = "test-noop-policy"

                def plan(self, finding):
                    return []
            return Noop()

        assert "test-noop-policy" in registry.get_registry("policy")
        assert registry.create(
            "policy", "test-noop-policy", None).plan(finding()) == []

    def test_action_kinds_stable(self):
        assert ACTION_KINDS == ("migrate-file", "resize-threads",
                                "throttle-checkpoint", "io-chunk")
