"""repro.obs: self-telemetry registry, wire verb, rollup, dashboard
(ISSUE 7 acceptance).

Covers the MetricsRegistry semantics (get-or-create, type conflicts,
snapshot/delta algebra, lock-correct concurrent increments), the
``metrics`` wire verb round-tripping through loopback/tcp/spool
transports, the fleet rollup (counters sum, gauges max, histogram bins
add — per-rank snapshots plus the collector's own registry), the
instrumented profiler surface (report.metrics / health / chrome-trace
counter events), and the offline HTML dashboard golden ids for both a
live local session and a spool-capture replay.
"""
import os
import threading

import pytest

from repro.core.counters import SIZE_BIN_NAMES, size_bin
from repro.core.runtime import DarshanRuntime
from repro.core.session import ProfileServer
from repro.fleet import CollectorServer, FleetCollector, payloads
from repro.link import Message, SpoolTransport, TcpTransport, decode, encode
from repro.obs.metrics import (MetricsRegistry, default_registry,
                               empty_snapshot, health_summary,
                               merge_snapshots, reset_default_registry,
                               snapshot_delta)
from repro.profiler import Profiler, ProfilerOptions
from repro.profiler.report import Report

DASHBOARD_IDS = ('id="per-file-heatmap"', 'id="per-rank-heatmap"',
                 'id="size-hist"', 'id="findings"', 'id="tune-audit"',
                 'id="health-panel"', 'id="metrics"',
                 'id="dashboard-data"')


# ------------------------------------------------------------- registry
def test_registry_get_or_create_and_type_conflict():
    reg = MetricsRegistry()
    c = reg.counter("x.count")
    assert reg.counter("x.count") is c          # same instrument back
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("x.level")
    g.set(2.5)
    assert reg.gauge("x.level").value == 2.5
    h = reg.histogram("x.sizes")
    h.observe(4096)
    assert reg.histogram("x.sizes").count == 1
    # one namespace across all three types: re-registering a name as a
    # different instrument is a bug, not a fresh metric
    with pytest.raises(ValueError, match="different instrument type"):
        reg.gauge("x.count")
    with pytest.raises(ValueError, match="different instrument type"):
        reg.counter("x.sizes")


def test_histogram_buckets_are_the_darshan_size_bins():
    h = MetricsRegistry().histogram("h")
    values = [0, 99, 100, 4095, 65536, 10_000_000, 5_000_000_000]
    for v in values:
        h.observe(v)
    counts = h.counts
    assert len(counts) == len(SIZE_BIN_NAMES)
    for v in values:
        assert counts[size_bin(v)] > 0          # same bin vocabulary
    assert sum(counts) == h.count == len(values)
    assert h.sum == float(sum(values))


def test_snapshot_delta_windows_counters_and_hists():
    reg = MetricsRegistry()
    reg.counter("c").inc(10)
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(50)
    mark = reg.snapshot()
    reg.counter("c").inc(3)
    reg.gauge("g").set(0.25)                    # gauges are levels
    reg.histogram("h").observe(50)
    reg.histogram("h").observe(5000)
    reg.counter("new").inc(7)                   # born after the mark
    d = reg.delta(mark)
    assert d["counters"] == {"c": 3, "new": 7}
    assert d["gauges"]["g"] == 0.25
    h = d["histograms"]["h"]
    assert h["count"] == 2 and h["sum"] == 5050.0
    assert h["counts"][size_bin(50)] == 1
    assert h["counts"][size_bin(5000)] == 1
    # no mark -> the delta IS the snapshot (first window of a session)
    assert snapshot_delta(None, reg.snapshot()) == reg.snapshot()


def test_merge_snapshots_sums_counters_maxes_gauges_adds_bins():
    a = {"counters": {"c": 2}, "gauges": {"g": 0.5, "only_a": 9.0},
         "histograms": {"h": {"counts": [1, 0, 2], "count": 3,
                              "sum": 30.0}}}
    b = {"counters": {"c": 5, "d": 1}, "gauges": {"g": 3.0},
         "histograms": {"h": {"counts": [0, 4, 1, 7], "count": 12,
                              "sum": 70.0}}}
    m = merge_snapshots([a, None, b, empty_snapshot()])
    assert m["counters"] == {"c": 7, "d": 1}
    assert m["gauges"] == {"g": 3.0, "only_a": 9.0}   # worst level wins
    h = m["histograms"]["h"]
    assert h["counts"] == [1, 4, 3, 7]          # ragged lengths align
    assert h["count"] == 15 and h["sum"] == 100.0
    # merge never mutates its inputs (rank slices are re-merged on
    # every report() call)
    assert a["histograms"]["h"]["counts"] == [1, 0, 2]


def test_concurrent_increments_lose_nothing():
    reg = MetricsRegistry()
    n_threads, n_incs = 8, 2000

    def work():
        c = reg.counter("shared")
        h = reg.histogram("sizes")
        for _ in range(n_incs):
            c.inc()
            h.observe(4096)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("shared").value == n_threads * n_incs
    assert reg.histogram("sizes").count == n_threads * n_incs
    assert sum(reg.histogram("sizes").counts) == n_threads * n_incs


def test_default_registry_is_process_global_until_reset():
    reg = reset_default_registry()
    assert default_registry() is reg
    reg.counter("x").inc()
    fresh = reset_default_registry()
    assert fresh is default_registry() and fresh is not reg
    assert fresh.snapshot()["counters"] == {}


# --------------------------------------------------------------- health
def test_health_summary_ok_degraded_and_listener_fold():
    ok = health_summary(empty_snapshot())
    assert ok["status"] == "ok"
    assert all(c["status"] == "ok" for c in ok["checks"].values())
    bad = health_summary({"counters": {"trace.dropped": 3,
                                       "link.tcp.resends": 1}})
    assert bad["status"] == "degraded"
    assert bad["checks"]["trace-drops"]["value"] == 3
    assert bad["checks"]["tcp-retries"]["status"] == "degraded"
    assert bad["checks"]["tune-failures"]["status"] == "ok"
    # pre-metrics payloads: the report-level listener_errors dict still
    # degrades the listener check
    folded = health_summary(None, listener_errors={"det": 2})
    assert folded["checks"]["listener-errors"]["value"] == 2
    assert folded["status"] == "degraded"


# ------------------------------------------------------------ wire verb
def test_metrics_verb_loopback_query_answers_collector_registry():
    coll = FleetCollector(detectors=[])
    coll.ingest_line(encode("hello", 0, {"nprocs": 1}))
    reply = decode(coll.ingest_line(encode("metrics", 0)))
    assert reply.kind == "metrics"
    counters = reply.payload["metrics"]["counters"]
    # the reply reflects the collector's own registry, including the
    # lines that carried this very exchange
    assert counters["collector.hellos"] == 1
    assert counters["collector.lines"] >= 2


def test_metrics_verb_tcp_query_against_collector_and_profile_server():
    coll = FleetCollector(detectors=[])
    server = CollectorServer(coll, idle_timeout_s=1.0)
    try:
        with TcpTransport("127.0.0.1", server.port) as t:
            reply = t.request(Message("metrics"))
            assert reply.kind == "metrics"
            assert reply.payload["metrics"]["counters"]["collector.lines"] >= 1
    finally:
        server.close()
    # a ProfileServer answers with its session runtime's registry
    rt = DarshanRuntime()
    rt.metrics.counter("runtime.listener_errors").inc(5)
    srv = ProfileServer(runtime=rt)
    try:
        with TcpTransport("127.0.0.1", srv.port) as t:
            reply = t.request(Message("metrics"))
            assert reply.kind == "metrics"
            counters = reply.payload["metrics"]["counters"]
            assert counters["runtime.listener_errors"] == 5
    finally:
        srv.close()


def test_metrics_verb_spool_push_lands_in_rank_slice(tmp_path):
    spool = str(tmp_path / "spool")
    reg = MetricsRegistry()
    reg.counter("runtime.listener_errors").inc(2)
    reg.gauge("insight.poll_lag_s").set(0.75)
    with SpoolTransport(spool, name="rank00003") as t:
        # a spool cannot answer a query; the push form writes the
        # snapshot into the capture instead
        assert t(encode("metrics", 3, {"push": True,
                                       "metrics": reg.snapshot()})) is None
    coll = FleetCollector(detectors=[])
    assert coll.ingest_spool(spool) == 1
    slice_metrics = coll.ranks[3].metrics
    assert slice_metrics["counters"]["runtime.listener_errors"] == 2
    assert slice_metrics["gauges"]["insight.poll_lag_s"] == 0.75
    # and the rollup folds the pushed snapshot into the fleet metrics
    fleet = coll.report()
    assert fleet.metrics["counters"]["runtime.listener_errors"] == 2


# ---------------------------------------------------------- fleet rollup
def _report_with_metrics(rank, snap):
    rt = DarshanRuntime()
    from repro.core.session import ProfileSession
    sess = ProfileSession(rt, auto_attach=False)
    sess.start()
    rt.posix_open(5, f"/data/r{rank}.bin", 0.0, 0.001)
    rt.posix_read(5, None, 8192, 0.1, 0.11, advance=True)
    rep = sess.stop()
    rep.metrics = snap
    return payloads.encode_report(rank, rep, nprocs=2, metrics=snap)


def test_fleet_rollup_merges_rank_snapshots_and_collector_registry():
    coll = FleetCollector(detectors=[])
    snap_a = {"counters": {"trace.dropped": 2},
              "gauges": {"insight.poll_lag_s": 0.2},
              "histograms": {"runtime.emit_ns": {
                  "counts": [0, 3, 0, 0, 0, 0, 0, 0, 0, 0],
                  "count": 3, "sum": 900.0}}}
    snap_b = {"counters": {"trace.dropped": 5},
              "gauges": {"insight.poll_lag_s": 0.9},
              "histograms": {"runtime.emit_ns": {
                  "counts": [1, 1, 0, 0, 0, 0, 0, 0, 0, 0],
                  "count": 2, "sum": 400.0}}}
    coll.ingest_line(_report_with_metrics(0, snap_a))
    coll.ingest_line(_report_with_metrics(1, snap_b))
    fleet = coll.report()
    m = fleet.metrics
    assert m["counters"]["trace.dropped"] == 7            # summed
    assert m["gauges"]["insight.poll_lag_s"] == 0.9       # max
    h = m["histograms"]["runtime.emit_ns"]
    assert h["counts"][:2] == [1, 4] and h["count"] == 5  # bins add
    # the collector's own registry rides along...
    assert m["counters"]["collector.reports"] == 2
    assert m["counters"]["collector.lines"] == 2
    # ...as do the report()-time staleness/rate gauges, one per rank
    assert "collector.rank_staleness_s.rank0" in m["gauges"]
    assert "collector.rank_staleness_s.rank1" in m["gauges"]
    assert m["gauges"]["collector.ingest_lines_per_s"] > 0
    # and the health rollup sees through the merge
    assert Report.from_fleet(fleet).health()["status"] == "degraded"


def _fleet_files(root, nranks, per_rank=4, size=16384):
    files = {}
    for r in range(nranks):
        d = os.path.join(str(root), f"r{r}")
        os.makedirs(d, exist_ok=True)
        files[r] = []
        for i in range(per_rank):
            p = os.path.join(d, f"{i:03d}.bin")
            with open(p, "wb") as f:
                f.write(b"x" * size)
            files[r].append(p)
    return files


def test_profiler_fleet_report_ships_and_rolls_up_metrics(tmp_path):
    files = _fleet_files(tmp_path, 2)

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=8192)

    report = Profiler(ProfilerOptions(mode="fleet", nranks=2)).run(workload)
    m = report.metrics
    assert m["counters"]["collector.reports"] == 2
    # per-rank runtime registries shipped inside the report payloads
    assert "runtime.emit_ns" in m["histograms"]
    for r in (0, 1):
        assert report.fleet.ranks[r].metrics   # slice kept its snapshot
        assert f"collector.rank_staleness_s.rank{r}" in m["gauges"]
    assert report.health()["status"] in ("ok", "degraded")
    d = report.to_dict()
    assert d["health"]["checks"] and d["metrics"]["counters"]
    # opting out: ship_metrics=False leaves the payloads metrics-free
    quiet = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                     metrics=False)).run(workload)
    assert all(not s.metrics for s in quiet.fleet.ranks.values())


# -------------------------------------------- local surface + exporters
def test_local_report_metrics_health_and_chrome_counters(tmp_path):
    p = tmp_path / "data.bin"
    p.write_bytes(b"x" * 262144)
    prof = Profiler(ProfilerOptions(mode="local"))
    with prof:
        with open(p, "rb") as f:
            while f.read(4096):
                pass
    report = prof.report
    m = report.metrics
    assert "trace.dropped" in m["counters"]
    assert "runtime.emit_ns" in m["histograms"]
    assert report.health()["status"] == "ok"
    assert report.to_dict()["health"]["status"] == "ok"
    trace = report.export("chrome_trace")
    counters = [e for e in trace["traceEvents"] if e.get("ph") == "C"]
    assert counters                              # ph "C" counter track
    assert any(e["name"] == "bandwidth_mb_s" for e in counters)
    tracked = {e["name"] for e in counters if e["name"] != "bandwidth_mb_s"}
    assert "trace.dropped" in tracked


def test_runtime_metrics_opt_out_and_shared_registry():
    off = DarshanRuntime(metrics=False)
    assert off.metrics is None
    off.enabled = True
    off.posix_open(5, "/x", 0.0, 0.001)
    off.posix_read(5, None, 4096, 0.0, 0.001, advance=True)   # no crash
    shared = MetricsRegistry()
    a = DarshanRuntime(metrics=shared)
    b = DarshanRuntime(metrics=shared)
    assert a.metrics is shared and b.metrics is shared
    # default: private per-runtime registries (per-rank isolation)
    assert DarshanRuntime().metrics is not DarshanRuntime().metrics


# ------------------------------------------------------------- dashboard
def test_dashboard_export_local_is_one_offline_html(tmp_path):
    p = tmp_path / "data.bin"
    p.write_bytes(b"y" * 131072)
    prof = Profiler(ProfilerOptions(mode="local"))
    with prof:
        with open(p, "rb") as f:
            while f.read(8192):
                pass
    out = str(tmp_path / "dashboard.html")
    prof.report.export("dashboard", out)
    with open(out) as f:
        html = f.read()
    for marker in DASHBOARD_IDS:
        assert marker in html, f"dashboard missing {marker}"
    assert html.startswith("<!DOCTYPE html>")
    assert "http://" not in html.replace("http://www.w3.org", "")
    assert str(p) in html                        # the per-file row label


def test_dashboard_renders_fleet_spool_replay(tmp_path):
    from repro.obs.dashboard import render_dashboard
    files = _fleet_files(tmp_path, 2)
    spool = str(tmp_path / "spool")

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=4096)

    live = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                    spool_dir=spool)).run(workload)
    # the finished spool dir is a capture: a fresh collector replays it
    # into the same aggregate, and the dashboard renders from that
    coll = FleetCollector(detectors=[])
    assert coll.ingest_spool(spool) > 0
    replayed = Report.from_fleet(coll.report())
    assert replayed.counters() == live.counters()
    html = render_dashboard(replayed)
    for marker in DASHBOARD_IDS:
        assert marker in html, f"replay dashboard missing {marker}"
    assert ">rank 0</text>" in html and ">rank 1</text>" in html
    assert replayed.metrics["counters"]["collector.lines"] > 0


def test_export_all_writes_dashboard_html(tmp_path):
    p = tmp_path / "data.bin"
    p.write_bytes(b"z" * 65536)
    prof = Profiler(ProfilerOptions(mode="local",
                                    exporters=("json_report", "dashboard")))
    with prof:
        with open(p, "rb") as f:
            f.read()
    out = prof.report.export_all(str(tmp_path / "exports"))
    assert out["dashboard"].endswith("dashboard.html")
    with open(out["dashboard"]) as f:
        assert 'id="health-panel"' in f.read()
