"""HLO cost-parser tests: trip-count correction validated against XLA's
own cost analysis on unrolled twin graphs; collective byte counting."""
import jax
import jax.numpy as jnp
import pytest

from repro.perf.hlo_analysis import analyze_hlo_text, parse_hlo


def _scanned(x, ws):
    def body(h, w):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(body, x, ws)
    return h


def _unrolled(x, ws):
    for i in range(ws.shape[0]):
        x = jnp.tanh(x @ ws[i])
    return x


def test_scan_flops_match_unrolled_xla_cost():
    L, B, D = 12, 128, 256
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    scanned = jax.jit(_scanned).lower(x, ws).compile()
    unrolled = jax.jit(_unrolled).lower(x, ws).compile()
    ps = analyze_hlo_text(scanned.as_text())
    xla_u = unrolled.cost_analysis()["flops"]
    assert ps["unknown_trip_whiles"] == 0
    # XLA undercounts the scan by ~L x; the parser must not
    assert scanned.cost_analysis()["flops"] < xla_u / 2
    assert abs(ps["flops"] - xla_u) / xla_u < 0.05


def test_parser_flops_match_xla_on_unrolled():
    L, B, D = 6, 64, 128
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    unrolled = jax.jit(_unrolled).lower(x, ws).compile()
    pu = analyze_hlo_text(unrolled.as_text())
    xla = unrolled.cost_analysis()["flops"]
    assert abs(pu["flops"] - xla) / xla < 0.05


def test_grad_of_scan_flops_scale_with_trips():
    L, B, D = 8, 64, 128
    def loss(x, ws):
        return jnp.sum(_scanned(x, ws).astype(jnp.float32) ** 2)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = jax.jit(jax.grad(loss, argnums=1)).lower(x, ws).compile()
    p = analyze_hlo_text(c.as_text())
    # fwd + bwd >= 3 matmuls per layer
    analytic = 3 * 2 * B * D * D * L
    assert p["flops"] > 0.8 * analytic


def test_parse_hlo_structure():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(lambda a: a @ a).lower(x).compile().as_text()
    comps, entry = parse_hlo(txt)
    assert entry in comps
    ops = [i.opcode for i in comps[entry].instrs]
    assert "dot" in ops or any("dot" in o for o in ops) or \
        any(i.opcode == "fusion" for i in comps[entry].instrs)


def test_dtype_byte_accounting():
    x = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    txt = jax.jit(lambda a: (a @ a).astype(jnp.float32)) \
        .lower(x).compile().as_text()
    p = analyze_hlo_text(txt)
    # dot reads 2 x bf16 (8KB each) and writes ~bf16/f32 output
    assert p["bytes"] >= 2 * 64 * 64 * 2
