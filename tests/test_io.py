"""repro.io ingest engine: buffer pool semantics + obs counters, zero-
copy readers, readahead no-op guarantees, small-file coalescing,
adaptive chunking through the tune closed loop, attach-layer preadv
instrumentation, and the Pipeline prefetch feeder lifecycle fix."""
import gc
import os
import threading
import time

import pytest

from repro.data.pipeline import Pipeline
from repro.data.readers import READERS, posix_read_file, resolve_reader
from repro.io import (BufferPool, CoalescingReader, PooledData,
                      fadvise, mmap_read_file, plan_coalesced,
                      pooled_read_file, pooled_read_view, read_coalesced,
                      read_into)
from repro.io.adaptive import (CHUNK_LADDER, DEPTH_LADDER, AdaptiveChunker,
                               adaptive_read_file)
from repro.io.buffers import _size_class
from repro.obs.metrics import MetricsRegistry
from repro.perf.hillclimb import HillClimb1D


def make_files(root, sizes, seed=0):
    paths = []
    for i, n in enumerate(sizes):
        p = os.path.join(str(root), f"f{i:04d}.bin")
        with open(p, "wb") as f:
            f.write(bytes((i + j) % 251 for j in range(n)))
        paths.append(p)
    return paths


# ---------------------------------------------------------------- buffers
class TestBufferPool:
    def test_size_classes_are_powers_of_two(self):
        assert _size_class(1) == 4096
        assert _size_class(4096) == 4096
        assert _size_class(4097) == 8192
        assert _size_class(1 << 20) == 1 << 20
        assert _size_class((1 << 20) + 1) == 1 << 21

    def test_hit_miss_resize_counters(self):
        reg = MetricsRegistry()
        pool = BufferPool(registry=reg)
        b1 = pool.acquire(10_000)            # miss + resize (new class)
        assert len(b1) == 16384
        pool.release(b1)
        b2 = pool.acquire(12_000)            # same class: hit
        assert b2 is b1
        assert reg.counter("io.pool.misses").value == 1
        assert reg.counter("io.pool.hits").value == 1
        assert reg.counter("io.pool.resizes").value == 1
        pool.acquire(1 << 20)                # new class: miss + resize
        assert reg.counter("io.pool.misses").value == 2
        assert reg.counter("io.pool.resizes").value == 2

    def test_release_bounds_and_evictions(self):
        reg = MetricsRegistry()
        pool = BufferPool(max_per_class=2, registry=reg)
        bufs = [bytearray(4096) for _ in range(4)]
        for b in bufs:
            pool.release(b)
        assert reg.counter("io.pool.evictions").value == 2
        assert pool.held_bytes == 2 * 4096

    def test_max_bytes_cap(self):
        pool = BufferPool(max_bytes=8192, max_per_class=100,
                          registry=MetricsRegistry())
        pool.release(bytearray(8192))
        pool.release(bytearray(8192))        # would exceed the cap
        assert pool.held_bytes == 8192

    def test_foreign_buffers_never_pooled(self):
        pool = BufferPool(registry=MetricsRegistry())
        pool.release(bytearray(1000))        # not a size class
        pool.release(bytearray(100))         # below the min class
        assert pool.held_bytes == 0

    def test_clear(self):
        pool = BufferPool(registry=MetricsRegistry())
        pool.release(pool.acquire(4096))
        assert pool.held_bytes > 0
        pool.clear()
        assert pool.held_bytes == 0


class TestPooledReaders:
    @pytest.mark.parametrize("size", [0, 1, 4095, 4096, 4097,
                                      (1 << 20) - 1, 1 << 20,
                                      (1 << 20) + 1, 3 * (1 << 20) + 17])
    def test_pooled_read_byte_exact(self, tmp_path, size):
        [p] = make_files(tmp_path, [size])
        want = posix_read_file(p)
        pool = BufferPool(registry=MetricsRegistry())
        assert pooled_read_file(p, pool=pool) == want
        assert pooled_read_file(p, chunk_size=4096, io_depth=3,
                                pool=pool) == want

    def test_read_into_short_on_eof(self, tmp_path):
        [p] = make_files(tmp_path, [1000])
        fd = os.open(p, os.O_RDONLY)
        try:
            buf = bytearray(4096)
            got = read_into(fd, memoryview(buf), 4096, chunk_size=256)
            assert got == 1000
            assert bytes(buf[:got]) == posix_read_file(p)
        finally:
            os.close(fd)

    def test_pooled_view_lease_lifecycle(self, tmp_path):
        [p] = make_files(tmp_path, [10_000])
        pool = BufferPool(registry=MetricsRegistry())
        lease = pooled_read_view(p, pool=pool)
        assert isinstance(lease, PooledData)
        assert len(lease) == 10_000
        assert bytes(lease) == posix_read_file(p)
        assert pool.held_bytes == 0          # buffer still leased out
        lease.release()
        assert pool.held_bytes == _size_class(10_000)
        lease.release()                      # double release is a no-op
        with pytest.raises(ValueError):
            lease.view                       # view is gone after release

    def test_view_buffer_recycled_between_reads(self, tmp_path):
        paths = make_files(tmp_path, [5000, 6000])
        pool = BufferPool(registry=MetricsRegistry())
        a = pooled_read_view(paths[0], pool=pool)
        data_a = bytes(a)
        a.release()
        b = pooled_read_view(paths[1], pool=pool)
        assert bytes(b) == posix_read_file(paths[1])
        assert data_a == posix_read_file(paths[0])   # copy unaffected
        b.release()

    def test_throttle_sees_all_bytes(self, tmp_path):
        [p] = make_files(tmp_path, [100_000])
        seen = []
        pooled_read_file(p, chunk_size=16_384, throttle=seen.append,
                         pool=BufferPool(registry=MetricsRegistry()))
        assert sum(seen) == 100_000


# -------------------------------------------------------------- readahead
class TestReadahead:
    def test_fadvise_modes(self, tmp_path):
        [p] = make_files(tmp_path, [8192])
        fd = os.open(p, os.O_RDONLY)
        try:
            for mode in ("normal", "sequential", "random", "willneed",
                         "dontneed"):
                assert fadvise(fd, mode) in (True, False)
            with pytest.raises(ValueError):
                fadvise(fd, "psychic")
        finally:
            os.close(fd)

    @pytest.mark.parametrize("size", [0, 1, 4096, 100_000])
    def test_mmap_read_byte_exact(self, tmp_path, size):
        [p] = make_files(tmp_path, [size])
        assert mmap_read_file(p) == posix_read_file(p)

    def test_mmap_throttle_charged_once(self, tmp_path):
        [p] = make_files(tmp_path, [50_000])
        seen = []
        mmap_read_file(p, throttle=seen.append)
        assert seen == [50_000]


# --------------------------------------------------------------- coalesce
class TestCoalesce:
    def test_plan_respects_batch_bytes(self, tmp_path):
        paths = make_files(tmp_path, [1000] * 10)
        batches = plan_coalesced(paths, batch_bytes=3500)
        assert [len(b) for b in batches] == [3, 3, 3, 1]
        assert [p for b in batches for p, _ in b] == sorted(paths)

    def test_oversized_file_gets_own_batch(self, tmp_path):
        paths = make_files(tmp_path, [100, 10_000, 100])
        batches = plan_coalesced(paths, batch_bytes=1000)
        assert [len(b) for b in batches] == [1, 1, 1]

    def test_read_coalesced_views_byte_exact(self, tmp_path):
        sizes = [0, 1, 5000, 4096, 12_345]
        paths = make_files(tmp_path, sizes)
        pool = BufferPool(registry=MetricsRegistry())
        for batch in plan_coalesced(paths, batch_bytes=16_384):
            cb = read_coalesced(batch, pool=pool, chunk_size=4096)
            for p, view in cb:
                assert bytes(view) == posix_read_file(p), p
            cb.release()
        assert pool.held_bytes > 0           # releases landed back

    def test_dropin_reader_serves_whole_corpus(self, tmp_path):
        paths = make_files(tmp_path, [3000] * 9)
        reg = MetricsRegistry()
        rdr = CoalescingReader(paths, batch_bytes=10_000,
                               pool=BufferPool(registry=reg), registry=reg)
        for p in sorted(paths):
            assert rdr(p) == posix_read_file(p)
        # 9 files at ~3 per batch: 3 gather reads, everything coalesced
        assert reg.counter("io.coalesce.batched_reads").value == 3
        assert reg.counter("io.coalesce.coalesced_files").value == 9
        assert reg.counter("io.coalesce.fallbacks").value == 0

    def test_dropin_reader_any_order(self, tmp_path):
        paths = make_files(tmp_path, [2000] * 8, seed=3)
        import random
        rng = random.Random(5)
        shuffled = list(paths)
        rng.shuffle(shuffled)
        rdr = CoalescingReader(paths, batch_bytes=5000,
                               registry=MetricsRegistry(),
                               pool=BufferPool(registry=MetricsRegistry()))
        for p in shuffled:
            assert rdr(p) == posix_read_file(p)

    def test_unknown_path_falls_back(self, tmp_path):
        paths = make_files(tmp_path, [1000, 1000])
        reg = MetricsRegistry()
        rdr = CoalescingReader(paths[:1], registry=reg,
                               pool=BufferPool(registry=MetricsRegistry()))
        assert rdr(paths[1]) == posix_read_file(paths[1])
        assert reg.counter("io.coalesce.fallbacks").value == 1

    def test_cache_bytes_bounded(self, tmp_path):
        paths = make_files(tmp_path, [4000] * 10)
        rdr = CoalescingReader(paths, batch_bytes=40_000, cache_bytes=8000,
                               registry=MetricsRegistry(),
                               pool=BufferPool(registry=MetricsRegistry()))
        rdr(sorted(paths)[0])                # one batch read caches siblings
        assert rdr._cache_held <= 8000

    def test_ambient_reader_entry(self, tmp_path):
        from repro.io.coalesce import (coalesced_read_file,
                                       reset_ambient_readers)
        paths = make_files(tmp_path, [1500] * 6)
        reset_ambient_readers()
        try:
            for p in sorted(paths):
                assert coalesced_read_file(p) == posix_read_file(p)
        finally:
            reset_ambient_readers()


# --------------------------------------------------------------- adaptive
class TestHillClimb:
    def test_climbs_toward_better_scores(self):
        hc = HillClimb1D([1, 2, 4, 8, 16], start_index=0)
        # score grows with the value: climber should end at the top rung
        for _ in range(32):
            if hc.settled:
                break
            hc.observe(float(hc.value))
        assert hc.settled and hc.best == 16

    def test_retreats_on_regression(self):
        hc = HillClimb1D([1, 2, 4, 8, 16], start_index=2)
        # scores peak at the starting value
        for _ in range(32):
            if hc.settled:
                break
            hc.observe(100.0 if hc.value == 4 else 10.0)
        assert hc.settled and hc.best == 4

    def test_reset_restarts(self):
        hc = HillClimb1D([1, 2, 4], start_index=1)
        while not hc.settled:
            hc.observe(1.0)
        hc.reset()
        assert not hc.settled


class TestAdaptiveChunker:
    def test_window_advances_knobs(self):
        ch = AdaptiveChunker(window_bytes=1000, registry=MetricsRegistry())
        snaps = set()
        for _ in range(64):
            ch.note(1000, 0.001)
            snaps.add((ch.chunk_size, ch.io_depth))
        assert len(snaps) > 1                # the climb actually moved
        assert all(c in CHUNK_LADDER and d in DEPTH_LADDER
                   for c, d in snaps)

    def test_set_pins_and_reset_unpins(self):
        ch = AdaptiveChunker(window_bytes=100, registry=MetricsRegistry())
        snap = ch.set(chunk_size=4 << 20, io_depth=2)
        assert snap["pinned"] and snap["settled"]
        assert ch.chunk_size == 4 << 20 and ch.io_depth == 2
        for _ in range(16):
            ch.note(1000, 0.001)             # pinned: windows can't move it
        assert ch.chunk_size == 4 << 20 and ch.io_depth == 2
        snap = ch.reset()
        assert not snap["pinned"]

    def test_adaptive_read_feeds_chunker(self, tmp_path):
        [p] = make_files(tmp_path, [60_000])
        ch = AdaptiveChunker(window_bytes=50_000,
                             registry=MetricsRegistry())
        assert adaptive_read_file(
            p, chunker=ch,
            pool=BufferPool(registry=MetricsRegistry())) \
            == posix_read_file(p)
        assert ch.snapshot()["windows"] == 1

    def test_io_chunk_action_through_applier(self):
        from repro.tune.actions import TuneAction
        from repro.tune.applier import TuneApplier
        ch = AdaptiveChunker(registry=MetricsRegistry())
        app = TuneApplier(rank=0).bind(io_chunker=ch)
        ack = app.apply(TuneAction(
            action_id="io1", kind="io-chunk",
            params={"chunk_size": 2 << 20, "io_depth": 4}))
        assert ack.status == "applied"
        assert ack.after["chunk_size"] == 2 << 20
        assert ch.chunk_size == 2 << 20 and ch.io_depth == 4
        ack = app.apply(TuneAction(action_id="io2", kind="io-chunk",
                                   params={"reset": True}))
        assert ack.status == "applied" and not ack.after["pinned"]
        ack = app.apply(TuneAction(action_id="io3", kind="io-chunk",
                                   params={}))
        assert ack.status == "rejected"
        unbound = TuneApplier(rank=1)
        assert unbound.apply(TuneAction(
            action_id="io4", kind="io-chunk",
            params={"reset": True})).status == "rejected"

    def test_adaptive_io_policy_plans(self):
        from repro.insight.detectors import Finding
        from repro.tune.policies import make_builtin_policy
        pol = make_builtin_policy("adaptive-io")

        def finding(det):
            return Finding(detector=det, title=det, severity=0.7,
                           window=(0.0, 1.0), evidence={},
                           recommendation="", rank=0)

        widen = pol.plan(finding("straggler-read-tail"))
        assert widen[0].kind == "io-chunk"
        assert widen[0].params["chunk_size"] > \
            pol.plan(finding("random-read-thrash"))[0].params["chunk_size"]
        assert pol.plan(finding("small-file-storm"))[0].params == \
            {"reset": True}
        assert pol.plan(finding("checkpoint-stall")) == []


# ----------------------------------------------------- attach + pipeline
class TestInstrumentation:
    def test_preadv_recorded_by_attach_layer(self, tmp_path):
        from repro.core.attach import attach, detach, is_attached
        from repro.core.runtime import DarshanRuntime
        size = 3 * (1 << 20) + 123
        [p] = make_files(tmp_path, [size])
        rt = DarshanRuntime()
        rt.enabled = True
        attach(rt)
        try:
            data = pooled_read_file(
                p, chunk_size=1 << 20, io_depth=2,
                pool=BufferPool(registry=MetricsRegistry()))
        finally:
            detach()
        assert not is_attached()
        assert len(data) == size
        rec = rt.posix.snapshot()[p]
        # 3 MiB + tail at io_depth=2 x 1 MiB iovecs = exactly 2 preadv
        assert rec.get("POSIX_READS") == 2
        assert rec.get("POSIX_BYTES_READ") == size
        assert rec.get("POSIX_OPENS") == 1

    def test_detach_restores_preadv(self):
        from repro.core.attach import attach, detach
        from repro.core.runtime import DarshanRuntime
        orig = os.preadv
        attach(DarshanRuntime())
        assert os.preadv is not orig
        detach()
        assert os.preadv is orig


class TestReaderRegistry:
    def test_readers_table_complete(self):
        assert set(READERS) == {"posix", "sized", "pooled", "mmap",
                                "coalesced", "adaptive"}

    def test_resolve_reader(self):
        assert resolve_reader("pooled") is READERS["pooled"]
        assert resolve_reader(posix_read_file) is posix_read_file
        assert resolve_reader(None) is posix_read_file
        with pytest.raises(KeyError):
            resolve_reader("teleport")

    def test_all_readers_byte_exact(self, tmp_path):
        sizes = [0, 1, 4096, 100_000, (1 << 20) + 7]
        paths = make_files(tmp_path, sizes)
        from repro.io.coalesce import reset_ambient_readers
        reset_ambient_readers()
        try:
            for p in paths:
                want = posix_read_file(p)
                for key, reader in READERS.items():
                    assert reader(p) == want, (key, p)
        finally:
            reset_ambient_readers()

    def test_tiered_reader_accepts_names(self, tmp_path):
        from repro.data.tiers import StorageTier, TierManager, \
            make_tiered_reader
        root = str(tmp_path / "ssd")
        tm = TierManager({"ssd": StorageTier("ssd", root)})
        paths = make_files(tmp_path / "ssd", [2000])
        read = make_tiered_reader(tm, reader="pooled")
        assert read(paths[0]) == posix_read_file(paths[0])


# ---------------------------------------------- prefetch feeder lifecycle
def _feeder_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-prefetch-feeder"]


def _wait_no_feeders(timeout=5.0):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if not _feeder_threads():
            return True
        time.sleep(0.02)
    return not _feeder_threads()


class TestPrefetchLifecycle:
    def test_feeder_exits_when_consumer_abandons(self):
        """Regression: an abandoned prefetch iterator used to leave its
        daemon feeder blocked forever on the bounded queue's put."""
        assert not _feeder_threads()
        it = iter(Pipeline(list(range(10_000))).map(lambda x: x, 2)
                  .batch(4).prefetch(2))
        next(it)
        assert _feeder_threads(), "prefetch should run a feeder thread"
        it.close()                          # abandon mid-stream
        assert _wait_no_feeders(), "feeder thread leaked after close()"

    def test_feeder_exits_on_gc_abandonment(self):
        it = iter(Pipeline(list(range(10_000))).map(lambda x: x, 2)
                  .batch(4).prefetch(2))
        next(it)
        del it
        gc.collect()
        assert _wait_no_feeders(), "feeder thread leaked after GC"

    def test_abandonment_closes_upstream_source(self):
        """The consumer going away must run the upstream generator's
        ``finally`` (pools, leases, files) — not just kill the queue."""
        closed = threading.Event()

        def items():
            try:
                for i in range(10_000):
                    yield i
            finally:
                closed.set()

        it = iter(Pipeline(items()).map(lambda x: x, 1).prefetch(1))
        next(it)
        it.close()
        assert closed.wait(5.0), "upstream generator finally never ran"
        assert _wait_no_feeders()

    def test_errors_and_completion_still_work(self):
        def boom(x):
            if x == 7:
                raise RuntimeError("x7")
            return x

        with pytest.raises(RuntimeError, match="x7"):
            list(Pipeline(list(range(16))).map(boom, 2).prefetch(2))
        assert _wait_no_feeders()
        out = list(Pipeline(list(range(16))).map(lambda x: x * 2, 2)
                   .prefetch(3))
        assert out == [x * 2 for x in range(16)]
        assert _wait_no_feeders()

    def test_map_accepts_reader_names(self, tmp_path):
        paths = [str(p) for p in
                 (tmp_path / f"r{i}.bin" for i in range(6))]
        for i, p in enumerate(paths):
            with open(p, "wb") as f:
                f.write(os.urandom(3000 + i))
        want = [posix_read_file(p) for p in sorted(paths)]
        for key in READERS:
            got = [bytes(x)
                   for x in Pipeline(sorted(paths)).map(key, 2)]
            assert got == want, key
        with pytest.raises(KeyError):
            Pipeline(paths).map("warp-drive")
