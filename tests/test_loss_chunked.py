"""Vocabulary-chunked CE must match the full-logits CE exactly,
including non-divisible vocab sizes (padding path)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.loss import cross_entropy, masked_mean


@given(st.integers(17, 257), st.integers(1, 64))
@settings(deadline=None, max_examples=20)
def test_chunked_matches_full(vocab, chunk):
    cfg = get_config("qwen1.5-4b", reduced=True).replace(
        vocab_size=vocab, vocab_chunk=chunk)
    cfg_full = cfg.replace(vocab_chunk=0)
    ks = jax.random.split(jax.random.PRNGKey(vocab * 131 + chunk), 3)
    B, S, d = 2, 8, 16
    x = jax.random.normal(ks[0], (B, S, d))
    w = jax.random.normal(ks[1], (d, vocab))
    labels = jax.random.randint(ks[2], (B, S), 0, vocab)
    a = cross_entropy(x, w, labels, cfg)
    b = cross_entropy(x, w, labels, cfg_full)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_masked_mean_ignores_masked_positions():
    loss = jnp.asarray([[1.0, 100.0], [3.0, 100.0]])
    mask = jnp.asarray([[1.0, 0.0], [1.0, 0.0]])
    assert float(masked_mean(loss, mask)) == pytest.approx(2.0)


def test_ce_of_uniform_logits_is_log_vocab():
    cfg = get_config("qwen1.5-4b", reduced=True).replace(
        vocab_size=100, vocab_chunk=32)
    x = jnp.zeros((1, 4, 8))
    w = jnp.zeros((8, 100))
    labels = jnp.zeros((1, 4), jnp.int32)
    out = cross_entropy(x, w, labels, cfg)
    assert float(jnp.max(jnp.abs(out - jnp.log(100.0)))) < 1e-4
