"""Data pipeline, dataset sharding, JRecord and tier tests (incl.
hypothesis properties)."""
import os
import threading
import time

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.dataset import FileDataset
from repro.data.jrecord import JRecordReader, JRecordWriter, pack_files
from repro.data.pipeline import AUTOTUNE, Pipeline
from repro.data.readers import posix_read_file, sized_read_file

SETTINGS = dict(deadline=None, max_examples=30)


@given(st.integers(1, 50), st.integers(1, 8))
@settings(**SETTINGS)
def test_sharding_partitions_files(n_files, n_shards):
    ds = FileDataset(tuple(f"/f/{i}" for i in range(n_files)))
    seen = []
    for idx in range(n_shards):
        seen.extend(ds.shard(n_shards, idx).files)
    assert sorted(seen) == sorted(ds.files)          # exactly-once coverage


@given(st.integers(1, 40), st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_shuffle_is_permutation_and_deterministic(n, seed):
    ds = FileDataset(tuple(f"/f/{i}" for i in range(n)))
    a, b = ds.shuffle(seed), ds.shuffle(seed)
    assert a.files == b.files
    assert sorted(a.files) == sorted(ds.files)


def test_pipeline_preserves_order_and_batches():
    items = list(range(37))
    out = list(Pipeline(items).map(lambda x: x * 2, 4).batch(8))
    flat = [x for b in out for x in b]
    assert flat == [x * 2 for x in items]
    assert [len(b) for b in out] == [8, 8, 8, 8, 5]
    out2 = list(Pipeline(items).map(lambda x: x, 4)
                .batch(8, drop_remainder=True))
    assert [len(b) for b in out2] == [8, 8, 8, 8]


def test_pipeline_prefetch_overlaps():
    def slow(x):
        time.sleep(0.02)
        return x
    items = list(range(16))
    t0 = time.perf_counter()
    out = []
    for x in Pipeline(items).map(slow, 8).prefetch(4):
        time.sleep(0.02)          # consumer work overlapped with producers
        out.append(x)
    wall = time.perf_counter() - t0
    assert out == items
    assert wall < 16 * 0.04 * 0.8     # must beat fully-serial execution


def test_pipeline_propagates_exceptions():
    def boom(x):
        if x == 3:
            raise ValueError("boom")
        return x
    with pytest.raises(ValueError, match="boom"):
        list(Pipeline(range(8)).map(boom, 2).prefetch(2))


def test_pipeline_hedge_recovers_straggler():
    calls = {"n": 0}
    lock = threading.Lock()

    def sometimes_slow(x):
        with lock:
            calls["n"] += 1
            first = calls["n"] == 1
        if first and x == 0:
            time.sleep(0.5)       # straggler on first attempt only
        return x

    t0 = time.perf_counter()
    out = list(Pipeline(range(4)).map(sometimes_slow, 2).hedge(0.05))
    assert out == [0, 1, 2, 3]
    assert time.perf_counter() - t0 < 0.45


def test_pipeline_autotune_runs():
    out = list(Pipeline(list(range(100)))
               .map(lambda x: bytes(100), AUTOTUNE).batch(10))
    assert sum(len(b) for b in out) == 100


@given(st.lists(st.binary(min_size=0, max_size=2000), min_size=1,
                max_size=20))
@settings(**SETTINGS)
def test_jrecord_roundtrip(payloads):
    import tempfile
    tmp = tempfile.mkdtemp(prefix="jrec_")
    path = os.path.join(tmp, "shard.jrec")
    with JRecordWriter(path) as w:
        for p in payloads:
            w.write(p)
    r = JRecordReader(path)
    assert len(r) == len(payloads)
    assert list(r) == payloads                       # sequential scan
    for i in (0, len(payloads) - 1):
        assert r.read(i) == payloads[i]              # random access
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)


def test_jrecord_detects_corruption(tmp_path):
    path = str(tmp_path / "s.jrec")
    with JRecordWriter(path) as w:
        w.write(b"A" * 100)
    with open(path, "r+b") as f:
        f.seek(30)
        f.write(b"\xff")
    with pytest.raises(IOError, match="crc"):
        JRecordReader(path).read(0)


def test_readers_equivalent_but_different_read_counts(tmp_path):
    from repro.core import ProfileSession, reset_runtime
    p = tmp_path / "f.bin"
    p.write_bytes(b"r" * 300_000)
    rt = reset_runtime()
    with ProfileSession(rt) as s1:
        a = posix_read_file(str(p), chunk_size=65536)
    rep1 = s1.reports[0]
    rt = reset_runtime()
    with ProfileSession(rt) as s2:
        b = sized_read_file(str(p), chunk_size=65536)
    rep2 = s2.reports[0]
    assert a == b
    # paper-faithful reader pays the zero-length EOF probe
    assert rep1.posix.zero_reads == 1 and rep2.posix.zero_reads == 0
    assert rep1.posix.reads == rep2.posix.reads + 1


def test_pack_files_concatenates(tmp_path):
    files = []
    for i in range(5):
        f = tmp_path / f"{i}.bin"
        f.write_bytes(bytes([i]) * (100 + i))
        files.append(str(f))
    out = str(tmp_path / "packed.jrec")
    total = pack_files(files, out)
    assert total == sum(100 + i for i in range(5))
    rec = list(JRecordReader(out))
    assert [len(r) for r in rec] == [100 + i for i in range(5)]
