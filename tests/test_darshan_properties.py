"""Property-based tests (hypothesis) for counter/analysis invariants."""
import os

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import counters as C
from repro.core.analysis import analyze, summarize_module
from repro.core.records import FileRecord, ModuleBuffer, delta
from repro.core.runtime import DarshanRuntime

SETTINGS = dict(deadline=None, max_examples=40)

# a synthetic stream of (file_id, offset, length) read ops
read_ops = st.lists(
    st.tuples(st.integers(0, 4),                # file id
              st.integers(0, 1 << 20),          # offset
              st.integers(0, 1 << 22)),         # length
    min_size=1, max_size=60)


def _apply(rt: DarshanRuntime, ops):
    rt.enabled = True
    for i, (fid, off, length) in enumerate(ops):
        fd = 1000 + fid
        if rt.fd_state(fd) is None:
            rt.posix_open(fd, f"/data/f{fid}", rt.now(), rt.now())
        rt.posix_read(fd, off, length, rt.now(), rt.now(), advance=False)


@given(read_ops)
@settings(**SETTINGS)
def test_histogram_partitions_reads(ops):
    rt = DarshanRuntime()
    _apply(rt, ops)
    summary = summarize_module("POSIX", rt.posix.snapshot())
    assert sum(summary.read_size_hist) == summary.reads == len(ops)


@given(read_ops)
@settings(**SETTINGS)
def test_bytes_read_equals_sum_of_lengths(ops):
    rt = DarshanRuntime()
    _apply(rt, ops)
    summary = summarize_module("POSIX", rt.posix.snapshot())
    assert summary.bytes_read == sum(length for _, _, length in ops)


@given(read_ops)
@settings(**SETTINGS)
def test_consecutive_implies_sequential(ops):
    rt = DarshanRuntime()
    _apply(rt, ops)
    for rec in rt.posix.snapshot().values():
        consec = rec.get("POSIX_CONSEC_READS")
        seq = rec.get("POSIX_SEQ_READS")
        reads = rec.get("POSIX_READS")
        assert consec <= seq <= reads
        # first read of a file can never be classified
        assert seq <= max(reads - 1, 0)


@given(read_ops)
@settings(**SETTINGS)
def test_max_byte_read_is_max_extent(ops):
    rt = DarshanRuntime()
    _apply(rt, ops)
    extents = {}
    for fid, off, length in ops:
        path = f"/data/f{fid}"
        extents[path] = max(extents.get(path, 0), max(off + length - 1, 0))
    for path, rec in rt.posix.snapshot().items():
        assert rec.get("POSIX_MAX_BYTE_READ") == extents[path]


@given(read_ops, read_ops)
@settings(**SETTINGS)
def test_snapshot_delta_equals_window_ops(before, during):
    """delta(stop, start) must reflect exactly the ops in the window."""
    rt = DarshanRuntime()
    _apply(rt, before)
    start = rt.posix.snapshot()
    _apply(rt, during)
    stop = rt.posix.snapshot()
    d = delta(stop, start)
    total_reads = sum(rec.get("POSIX_READS") for rec in d.values())
    # opens inside the window also occur for new fds
    expected = len(during)
    assert total_reads == expected
    total_bytes = sum(rec.get("POSIX_BYTES_READ") for rec in d.values())
    assert total_bytes == sum(length for _, _, length in during)


@given(st.integers(0, 10**12))
@settings(**SETTINGS)
def test_size_bin_total_and_monotone(n):
    b = C.size_bin(n)
    assert 0 <= b < len(C.SIZE_BIN_NAMES)
    if n > 0:
        assert C.size_bin(n - 1) <= b


# -------------------------------------------------- link message codec
from repro.link import messages as link_messages  # noqa: E402

json_scalars = (st.none() | st.booleans()
                | st.integers(-(1 << 40), 1 << 40)
                | st.floats(allow_nan=False, allow_infinity=False)
                | st.text(max_size=40))
json_payloads = st.dictionaries(
    st.text(max_size=20),
    json_scalars | st.lists(json_scalars, max_size=5)
    | st.dictionaries(st.text(max_size=10), json_scalars, max_size=4),
    max_size=8)


@given(st.sampled_from(link_messages.KINDS), st.integers(0, 1 << 20),
       json_payloads)
@settings(**SETTINGS)
def test_link_codec_roundtrip(kind, rank, payload):
    """encode -> decode is the identity over every built-in kind, any
    rank, and arbitrary JSON payloads (incl. unicode and nesting)."""
    msg = link_messages.decode(link_messages.encode(kind, rank, payload))
    assert msg.kind == kind
    assert msg.rank == rank
    assert msg.payload == payload
    assert msg.v == link_messages.LINK_VERSION
    # a second trip is byte-stable (spool replay determinism)
    line = msg.encode()
    assert link_messages.decode(line).encode() == line


@given(st.text(max_size=200))
@settings(**SETTINGS)
def test_link_decode_raises_only_wire_errors(junk):
    """Arbitrary junk lines either decode (they happened to be a valid
    message) or raise WireError — never an unhandled exception type."""
    try:
        link_messages.decode(junk)
    except link_messages.WireError:
        pass


# ------------------------------------------------ columnar trace plane
import json  # noqa: E402

from repro.fleet import payloads as fleet_payloads  # noqa: E402
from repro.trace import Segment, SegmentColumns, TraceStore  # noqa: E402

finite_times = st.floats(0, 1e6, allow_nan=False, allow_infinity=False)
segment_rows = st.lists(
    st.builds(
        Segment,
        module=st.sampled_from(["POSIX", "STDIO"]),
        path=st.sampled_from([f"/d/f{i}" for i in range(5)])
        | st.text(min_size=1, max_size=24),
        op=st.sampled_from(["read", "write", "open", "stat", "seek",
                            "flush", "fsync"]),
        offset=st.integers(0, 1 << 50),
        length=st.integers(0, 1 << 40),
        start=finite_times,
        end=finite_times,
        thread=st.integers(0, (1 << 63) - 1)),
    max_size=50)


@given(segment_rows)
@settings(**SETTINGS)
def test_columns_roundtrip_is_identity(segs):
    """rows -> columnar store -> rows loses nothing: values, order,
    and duplicates all survive the structure-of-arrays packing."""
    cols = SegmentColumns.from_rows(segs)
    assert cols.to_rows() == segs
    assert len(cols) == len(segs)
    # interning is exact: every distinct string appears exactly once
    assert len(set(cols.paths)) == len(cols.paths)
    assert set(cols.paths) == {s.path for s in segs}


@given(segment_rows)
@settings(**SETTINGS)
def test_segments_columns_wire_roundtrip(segs):
    """The segments_columns payload survives a real JSON trip (the
    fleet wire) bit-exactly, including float timestamps."""
    obj = json.loads(json.dumps(
        fleet_payloads.encode_segments_columns(segs)))
    assert fleet_payloads.decode_segments_columns(obj).to_rows() == segs
    # and the legacy row codec agrees with the columnar one
    rows_obj = json.loads(json.dumps(fleet_payloads.encode_segments(segs)))
    assert fleet_payloads.decode_segments(rows_obj) == segs


@given(segment_rows, st.integers(1, 8))
@settings(**SETTINGS)
def test_ring_retains_exactly_the_newest(segs, capacity):
    store = TraceStore(capacity=capacity)
    for s in segs:
        store.add(s)
    assert store.snapshot().to_rows() == segs[-capacity:]
    assert store.dropped == max(0, len(segs) - capacity)
    assert len(store) == min(len(segs), capacity)


@given(segment_rows, st.sampled_from([None, 1e5]))
@settings(**SETTINGS)
def test_warehouse_archive_scan_is_row_exact(segs, slice_s):
    """SegmentColumns -> partitioned archive -> full scan loses
    nothing: every row survives the binary block codec and the
    (rank, time-slice) partitioning bit-exactly.  With time slicing
    off the single partition also preserves insertion order."""
    import shutil
    import tempfile

    from repro.warehouse import Archive, ArchiveWriter

    cols = SegmentColumns.from_rows(segs)
    root = tempfile.mkdtemp(prefix="wh_prop_")
    try:
        with ArchiveWriter(root, run="p", slice_s=slice_s) as w:
            w.add_batch(cols, rank=0)
        table = Archive(root).scan("p").table(sort=False)
        assert len(table) == len(segs)
        assert sorted(table.iter_tuples()) == sorted(
            cols.iter_tuples())
        if slice_s is None:
            assert table.to_rows() == segs
    finally:
        shutil.rmtree(root, ignore_errors=True)


# --------------------------------------------- obs metrics histograms
from repro.obs.metrics import (MetricsRegistry, merge_snapshots,  # noqa: E402
                               snapshot_delta)

observations = st.lists(st.integers(0, 1 << 40), min_size=1, max_size=80)


@given(observations)
@settings(**SETTINGS)
def test_obs_histogram_bins_partition_observations(values):
    """An obs Histogram conserves mass: the bin counts always sum to
    the observation count, every observation lands in its Darshan size
    bin, and the running sum is exact."""
    h = MetricsRegistry().histogram("h")
    for v in values:
        h.observe(v)
    counts = h.counts
    assert sum(counts) == h.count == len(values)
    assert h.sum == float(sum(values))
    expected = [0] * len(C.SIZE_BIN_NAMES)
    for v in values:
        expected[C.size_bin(v)] += 1
    assert counts == expected


@given(observations, observations)
@settings(**SETTINGS)
def test_obs_snapshot_algebra_conserves_counts(before, during):
    """delta and merge are inverse-ish: delta(start, stop) holds
    exactly the window's observations, and merging it back onto the
    start snapshot reproduces the full histogram."""
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for v in before:
        h.observe(v)
    mark = reg.snapshot()
    for v in during:
        h.observe(v)
    d = snapshot_delta(mark, reg.snapshot())
    assert d["histograms"]["h"]["count"] == len(during)
    assert sum(d["histograms"]["h"]["counts"]) == len(during)
    rebuilt = merge_snapshots([mark, d])
    assert rebuilt["histograms"]["h"] == reg.snapshot()["histograms"]["h"]


def test_eof_pattern_detector_threshold():
    rt = DarshanRuntime()
    rt.enabled = True
    for fid in range(10):
        fd = 2000 + fid
        rt.posix_open(fd, f"/d/f{fid}", 0.0, 0.0)
        rt.posix_read(fd, 0, 1000, 0.0, 0.0, advance=False)
        rt.posix_read(fd, 1000, 0, 0.0, 0.0, advance=False)   # EOF probe
    rep = analyze(rt.posix.snapshot(), {}, elapsed_s=1.0, stat_sizes=False)
    assert rep.has_eof_double_read_pattern()
    assert rep.zero_read_frac == pytest.approx(0.5)


# ----------------------------------------------------- relay frame codec
# arbitrary segment rows: wide int/float ranges so the delta + shuffle
# transforms face adversarial, not just realistic, inputs
segment_rows = st.lists(
    st.tuples(st.sampled_from(["POSIX", "STDIO"]),
              st.integers(0, 9),                      # path id
              st.sampled_from(["read", "write", "open"]),
              st.integers(0, (1 << 62) - 1),          # offset
              st.integers(0, (1 << 62) - 1),          # length
              st.floats(0.0, 1e6, allow_nan=False),   # start
              st.floats(0.0, 1e6, allow_nan=False),   # end
              st.integers(0, (1 << 63) - 1)),         # thread
    min_size=0, max_size=50)


def _to_columns(rows):
    from repro.core.dxt import Segment
    from repro.trace import SegmentColumns
    return SegmentColumns.from_rows(
        [Segment(m, f"/data/f{p}", op, off, ln, s, e, t)
         for m, p, op, off, ln, s, e, t in rows])


@given(segment_rows, st.booleans())
@settings(**SETTINGS)
def test_relay_frame_roundtrip(rows, compress):
    """encode_frame/decode_frame is the identity on any batch — every
    column byte-exact (floats included: the XOR-delta transform must be
    lossless on raw f64 bit patterns)."""
    from repro.relay import decode_frame, encode_frame
    cols = _to_columns(rows)
    payload = {"elapsed_s": 1.0, "segments_columns": cols}
    msg = decode_frame(encode_frame("report", 5, payload,
                                    compress=compress))
    got = msg.payload["segments_columns"]
    assert len(got) == len(cols)
    for name in ("module", "path", "op", "offset", "length", "start",
                 "end", "thread"):
        assert (getattr(got, name).tobytes()
                == getattr(cols, name).tobytes()), name
    assert list(got) == list(cols)


@given(segment_rows, st.data())
@settings(**SETTINGS)
def test_relay_frame_truncation_never_crashes(rows, data):
    """Any prefix of a valid frame must raise WireError — never an
    unhandled struct/zlib/numpy error, never a silent partial decode."""
    from repro.link import WireError
    from repro.relay import decode_frame, encode_frame
    frame = encode_frame("report", 0,
                         {"segments_columns": _to_columns(rows)})
    cut = data.draw(st.integers(0, len(frame) - 1))
    with pytest.raises(WireError):
        decode_frame(frame[:cut])


@given(segment_rows, st.data())
@settings(**SETTINGS)
def test_relay_frame_corruption_detected_or_equal(rows, data):
    """Flipping any byte either raises WireError or (for the rare CRC
    collision — none at these sizes) decodes to something; it must
    never crash with a non-wire error."""
    from repro.link import WireError
    from repro.relay import decode_frame, encode_frame
    frame = bytearray(encode_frame("report", 0,
                                   {"segments_columns": _to_columns(rows)}))
    pos = data.draw(st.integers(0, len(frame) - 1))
    bit = data.draw(st.integers(0, 7))
    frame[pos] ^= (1 << bit)
    try:
        decode_frame(bytes(frame))
    except WireError:
        pass


# ---------------------------------------------------------------------------
# repro.io reader parity: every fast-path reader is byte-identical to
# posix_read_file for arbitrary file sizes (empty, sub-chunk, exact
# chunk multiples, chunk +/- 1) and arbitrary chunk sizes.
# ---------------------------------------------------------------------------
import shutil  # noqa: E402
import tempfile  # noqa: E402

_chunk_sizes = st.sampled_from([1, 13, 4096, 1 << 16, 1 << 20])
_file_sizes = st.one_of(
    st.sampled_from([0, 1, 4095, 4096, 4097, (1 << 16) - 1, 1 << 16,
                     (1 << 16) + 1]),
    st.integers(0, 200_000),
)


@given(size=_file_sizes, chunk=_chunk_sizes, depth=st.integers(1, 16),
       seed=st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_io_readers_byte_identical_to_posix(size, chunk, depth, seed):
    import random

    from repro.data.readers import posix_read_file
    from repro.io import (BufferPool, CoalescingReader, mmap_read_file,
                          pooled_read_file, pooled_read_view)
    from repro.io.adaptive import AdaptiveChunker, adaptive_read_file
    from repro.obs.metrics import MetricsRegistry

    root = tempfile.mkdtemp(prefix="io_prop_")
    try:
        path = os.path.join(root, "f.bin")
        payload = bytes(random.Random(seed).getrandbits(8)
                        for _ in range(min(size, 4096)))
        with open(path, "wb") as f:
            # repeat a random block out to `size` (cheap at 200 KB max)
            while f.tell() < size:
                f.write(payload[:size - f.tell()] if payload else b"\0")
                if not payload:
                    break
            f.truncate(size)
        want = posix_read_file(path)
        assert len(want) == size

        pool = BufferPool(registry=MetricsRegistry())
        assert pooled_read_file(path, chunk_size=chunk, io_depth=depth,
                                pool=pool) == want
        lease = pooled_read_view(path, chunk_size=chunk, io_depth=depth,
                                 pool=pool)
        assert bytes(lease) == want
        lease.release()

        assert mmap_read_file(path) == want

        rdr = CoalescingReader([path], chunk_size=chunk, io_depth=depth,
                               pool=pool, registry=MetricsRegistry())
        assert rdr(path) == want

        ch = AdaptiveChunker(registry=MetricsRegistry())
        ch.set(chunk_size=chunk, io_depth=depth)
        assert adaptive_read_file(path, chunker=ch, pool=pool) == want
    finally:
        shutil.rmtree(root, ignore_errors=True)
