"""repro.warehouse: block format, partitioned archives, pushdown scans."""
import json
import os

import numpy as np
import pytest

from repro.insight.features import extract_columns
from repro.obs.metrics import MetricsRegistry
from repro.trace import Segment, SegmentColumns
from repro.warehouse import (Archive, ArchiveWriter, SegmentFile,
                             SegmentFileWriter, open_segment_file)
from repro.warehouse import format as wformat


def _cols(n=40, t0=0.0, dt=0.5, path_mod=3):
    rows = [Segment("POSIX" if i % 4 else "STDIO",
                    f"/d/f{i % path_mod}",
                    ("read", "write", "open", "seek")[i % 4],
                    i * 10, 100 + i, t0 + dt * i, t0 + dt * i + 0.01,
                    i % 2)
            for i in range(n)]
    return SegmentColumns.from_rows(rows)


def _same_rows(a: SegmentColumns, b: SegmentColumns):
    assert sorted(a.iter_tuples()) == sorted(b.iter_tuples())


# ------------------------------------------------------------- format
def test_segment_file_roundtrip(tmp_path):
    path = str(tmp_path / "one.seg")
    c1, c2 = _cols(30), _cols(7, t0=100.0)
    with SegmentFileWriter(path) as w:
        w.write_block(c1, rank=0)
        w.write_block(c2, rank=3)
        w.write_block(SegmentColumns.empty())      # ignored
    with SegmentFile(path) as sf:
        assert not sf.salvaged
        assert len(sf) == 2 and sf.rows == 37
        assert sf.blocks[0].rank == 0 and sf.blocks[1].rank == 3
        assert sf.blocks[1].t_min == pytest.approx(100.0)
        assert sf.blocks[1].t_max == pytest.approx(103.0)
        _same_rows(sf.read_block(0), c1)
        assert sf.read_block(0).to_rows() == c1.to_rows()
        _same_rows(sf.read_all(), SegmentColumns.concat([c1, c2]))


def test_segment_file_projection_decodes_only_requested(tmp_path):
    path = str(tmp_path / "p.seg")
    cols = _cols(20)
    with SegmentFileWriter(path) as w:
        w.write_block(cols)
    with SegmentFile(path) as sf:
        got = sf.read_block(0, columns=("start", "length"))
        np.testing.assert_array_equal(got.start, cols.start)
        np.testing.assert_array_equal(got.length, cols.length)
        # unprojected scalar columns come back zero-filled
        assert not got.offset.any()


def test_segment_file_salvages_torn_file(tmp_path):
    path = str(tmp_path / "torn.seg")
    c1, c2 = _cols(25), _cols(9, t0=50.0)
    with SegmentFileWriter(path) as w:
        w.write_block(c1)
        first_block_end = w._fh.tell()
        w.write_block(c2)
    # chop the footer/trailer plus half of the second block: the
    # reader must fall back to a sequential scan and keep block 1
    with open(path, "rb") as fh:
        data = fh.read()
    with open(path, "wb") as fh:
        fh.write(data[:first_block_end + 40])
    with SegmentFile(path) as sf:
        assert sf.salvaged
        assert len(sf) == 1
        _same_rows(sf.read_block(0), c1)


def test_open_rejects_non_segment_file(tmp_path):
    path = str(tmp_path / "junk.seg")
    with open(path, "wb") as fh:
        fh.write(b"definitely not a segment file")
    with pytest.raises(wformat.FormatError):
        SegmentFile(path)


def test_parquet_roundtrip_same_interface(tmp_path):
    pytest.importorskip("pyarrow")
    path = str(tmp_path / "one.parquet")
    c1, c2 = _cols(30), _cols(7, t0=100.0)
    with wformat.writer_for(path, codec="parquet") as w:
        w.write_block(c1, rank=1)
        w.write_block(c2, rank=2)
    with open_segment_file(path) as sf:          # extension dispatch
        assert sf.codec == "parquet"
        assert len(sf) == 2 and sf.rows == 37
        assert sf.blocks[0].rank == 1
        _same_rows(sf.read_block(0), c1)
        _same_rows(sf.read_all(), SegmentColumns.concat([c1, c2]))


def test_parquet_archive_scan(tmp_path):
    pytest.importorskip("pyarrow")
    cols = _cols(60)
    with ArchiveWriter(str(tmp_path), run="pq", codec="parquet",
                       slice_s=5.0) as w:
        w.add_batch(cols, rank=0)
    table = Archive(str(tmp_path)).scan("pq").table()
    _same_rows(table, cols)


# ------------------------------------------------------------ archive
def test_archive_partitions_by_rank_and_slice(tmp_path):
    cols = _cols(40, dt=1.0)                     # spans 0..39s
    with ArchiveWriter(str(tmp_path), run="r", slice_s=10.0) as w:
        w.add_batch(cols, rank=0)
        w.add_batch(cols, rank=1)
    parts = Archive(str(tmp_path)).partitions("r")
    assert len(parts) == 8                       # 2 ranks x 4 slices
    assert {(p.rank, p.slice) for p in parts} == \
        {(r, s) for r in (0, 1) for s in range(4)}
    for p in parts:
        assert p.t_min >= p.slice * 10.0
        assert p.t_max < (p.slice + 1) * 10.0


def test_scan_pushdown_prunes_partitions_and_is_exact(tmp_path):
    cols = _cols(40, dt=1.0)
    with ArchiveWriter(str(tmp_path), run="r", slice_s=10.0) as w:
        w.add_batch(cols, rank=0)
        w.add_batch(cols.shift_time(0.25), rank=1)
    scan = Archive(str(tmp_path)).scan("r").where(t0=12.0, t1=17.0,
                                                  ranks=[0])
    table = scan.table()
    _same_rows(table, cols.time_slice(12.0, 17.0))
    # 8 partitions exist; only rank 0 slice 1 overlaps [12, 17]
    assert scan.stats["partitions"] == 1
    assert scan.stats["partitions_pruned"] == 7
    assert scan.stats["rows_matched"] == len(table)


def test_scan_filters_ops_files_modules(tmp_path):
    cols = _cols(48)
    with ArchiveWriter(str(tmp_path), run="r", slice_s=None) as w:
        w.add_batch(cols, rank=0)
    arch = Archive(str(tmp_path))
    reads = arch.scan("r").where(ops=["read"]).table()
    assert len(reads) == int(cols.op_mask("read").sum())
    assert set(reads.to_rows()[i].op for i in range(len(reads))) \
        == {"read"}
    one_file = arch.scan("r").where(files=["/d/f1"]).table()
    assert all(s.path == "/d/f1" for s in one_file)
    sub = arch.scan("r").where(file_contains="f2").table()
    assert all("f2" in s.path for s in sub)
    stdio = arch.scan("r").where(modules=["STDIO"]).table()
    assert all(s.module == "STDIO" for s in stdio)


def test_archive_incremental_append_and_store_ingest(tmp_path):
    from repro.trace import TraceStore
    store = TraceStore(capacity=1000)
    for s in _cols(10).to_rows():
        store.add(s)
    w = ArchiveWriter(str(tmp_path), run="r", slice_s=None)
    assert w.ingest_store(store) == 10
    w.flush()
    for s in _cols(5, t0=100.0).to_rows():
        store.add(s)
    assert w.ingest_store(store) == 5             # only the new rows
    w.finalize()
    arch = Archive(str(tmp_path))
    assert arch.stats()["rows"] == 15
    # two flushes -> two immutable parts, both in the manifest
    assert len(arch.partitions("r")) == 2


def test_archive_salvages_parts_missing_from_manifest(tmp_path):
    cols = _cols(30)
    with ArchiveWriter(str(tmp_path), run="r", slice_s=None) as w:
        w.add_batch(cols, rank=0)
    os.unlink(str(tmp_path / "r" / "manifest.json"))
    arch = Archive(str(tmp_path))
    assert arch.runs() == ["r"]
    _same_rows(arch.scan("r").table(), cols)


def test_spool_compaction_tolerates_corrupt_lines(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    spool = str(tmp_path / "spool")
    data = tmp_path / "data.bin"
    data.write_bytes(os.urandom(16384))

    def workload(rank, io):
        io.read_file(str(data), chunk=4096)

    fleet = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                     spool_dir=spool)).run(workload)
    expect = fleet.segments_table()
    # corrupt one line mid-capture: compaction must skip it, count it,
    # and still archive every valid report
    victim = sorted(os.listdir(spool))[0]
    with open(os.path.join(spool, victim), "a") as fh:
        fh.write("this is not a wire line\n")
    metrics = MetricsRegistry()
    w = ArchiveWriter(str(tmp_path / "wh"), run="cap", slice_s=None,
                      metrics=metrics)
    assert w.ingest_spool(spool) == len(expect)
    w.finalize()
    snap = metrics.snapshot()["counters"]
    assert snap.get("warehouse.corrupt_lines", 0) >= 1
    table = Archive(str(tmp_path / "wh")).scan("cap").table()
    assert len(table) == len(expect)
    # times differ (each collector aligns onto its own clock) but the
    # payload columns are identical
    for name in ("module", "path", "op", "offset", "length"):
        got = sorted(t[:5] for t in table.iter_tuples())
        ref = sorted(t[:5] for t in expect.iter_tuples())
        assert got == ref


# -------------------------------------------------------------- query
def test_aggregate_matches_extract_columns(tmp_path):
    cols = _cols(80, dt=0.25)
    with ArchiveWriter(str(tmp_path), run="r", slice_s=5.0) as w:
        w.add_batch(cols, rank=0)
    arch = Archive(str(tmp_path))
    agg = {g["op"]: g for g in arch.scan("r").aggregate(by="op")}
    f = extract_columns(cols, 0.0, float(cols.end.max()))
    assert agg["read"]["rows"] == f.reads
    assert agg["write"]["rows"] == f.writes
    assert agg["read"]["bytes"] == f.bytes_read
    assert agg["write"]["bytes"] == f.bytes_written
    assert agg["read"]["busy_s"] == pytest.approx(f.read_busy_s)
    assert agg["read"]["avg_size"] == pytest.approx(f.avg_read_size)
    read_h, _write_h = arch.scan("r").size_histograms()
    assert read_h == f.read_size_hist


def test_aggregate_by_rank_file_and_time(tmp_path):
    cols = _cols(40, dt=1.0)
    with ArchiveWriter(str(tmp_path), run="r", slice_s=10.0) as w:
        w.add_batch(cols, rank=0)
        w.add_batch(cols, rank=1)
    arch = Archive(str(tmp_path))
    by_rank = arch.scan("r").aggregate(by="rank")
    assert [g["rank"] for g in by_rank] == [0, 1]
    assert by_rank[0]["rows"] == len(cols)
    by_file = arch.scan("r").aggregate(by="file")
    assert {g["file"] for g in by_file} == set(cols.paths)
    by_time = arch.scan("r").aggregate(by="time", bucket_s=10.0)
    assert [g["time"] for g in by_time] == [0.0, 10.0, 20.0, 30.0]
    assert sum(g["rows"] for g in by_time) == 2 * len(cols)


def test_dashboard_renders_from_archive(tmp_path):
    from repro.obs.dashboard import render_dashboard
    with ArchiveWriter(str(tmp_path), run="r", slice_s=10.0) as w:
        w.add_batch(_cols(40, dt=1.0), rank=0)
        w.add_batch(_cols(40, dt=1.0), rank=1)
    arch = Archive(str(tmp_path))
    out = str(tmp_path / "dash.html")
    html = render_dashboard(arch, out)           # Archive as data source
    for marker in ('id="per-file-heatmap"', 'id="per-rank-heatmap"',
                   'id="size-hist"', 'id="health-panel"',
                   'id="metrics"'):
        assert marker in html
    assert "rank 1" in html
    assert os.path.getsize(out) > 0


# ------------------------------------------------------------- wiring
def test_profiler_archive_dir_local_and_exporter(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    data = tmp_path / "d.bin"
    data.write_bytes(os.urandom(8192))
    prof = Profiler(ProfilerOptions(
        archive_dir=str(tmp_path / "wh"), archive_run="loc",
        archive_slice_s=None))
    with prof:
        with open(data, "rb") as fh:
            while fh.read(4096):
                pass
    _same_rows(Archive(str(tmp_path / "wh")).scan("loc").table(),
               prof.report.segments_table())
    # the "archive" exporter writes a directory through export()
    prof.report.export("archive", str(tmp_path / "wh2"))
    assert Archive(str(tmp_path / "wh2")).stats()["rows"] \
        == len(prof.report.segments_table())


def test_profiler_archive_dir_fleet_collects_per_rank(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    data = tmp_path / "d.bin"
    data.write_bytes(os.urandom(8192))

    def workload(rank, io):
        io.read_file(str(data), chunk=2048)

    rep = Profiler(ProfilerOptions(
        mode="fleet", nranks=2, archive_dir=str(tmp_path / "wh"),
        archive_run="flt")).run(workload)
    arch = Archive(str(tmp_path / "wh"))
    _same_rows(arch.scan("flt").table(), rep.segments_table())
    assert {p.rank for p in arch.partitions("flt")} == {0, 1}


def test_export_all_uses_exporter_ext_attribute(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions
    prof = Profiler(ProfilerOptions(exporters=(
        "json_report", "darshan_log", "dashboard", "archive")))
    with prof:
        pass
    out = prof.report.export_all(str(tmp_path / "out"))
    assert out["json_report"].endswith("json_report.json")
    assert out["darshan_log"].endswith("darshan_log.txt")
    assert out["dashboard"].endswith("dashboard.html")
    # extensionless exporters (archive) get a bare directory path
    assert out["archive"].endswith(os.path.join("out", "archive"))
    for path in out.values():
        assert os.path.exists(path)


def test_harness_archive_dir_requires_collect(tmp_path):
    from repro.fleet.collector import FleetCollector
    from repro.fleet.harness import simulate_fleet
    with pytest.raises(ValueError, match="collect=True"):
        simulate_fleet(1, lambda r, io: None,
                       FleetCollector(detectors=[]), collect=False,
                       archive_dir=str(tmp_path / "wh"))


def test_options_validate_archive_fields():
    from repro.profiler import ProfilerOptions
    from repro.profiler.options import ProfilerOptionsError
    with pytest.raises(ProfilerOptionsError):
        ProfilerOptions(archive_codec="csv").validate()
    with pytest.raises(ProfilerOptionsError):
        ProfilerOptions(archive_slice_s=0).validate()
    with pytest.raises(ProfilerOptionsError):
        ProfilerOptions(archive_run="").validate()
    ProfilerOptions(archive_dir="x", archive_slice_s=None).validate()


# ---------------------------------------------------------------- CLI
def test_cli_compact_stats_query(tmp_path, capsys):
    from repro.profiler import Profiler, ProfilerOptions
    from repro.warehouse.cli import main
    spool = str(tmp_path / "spool")
    data = tmp_path / "d.bin"
    data.write_bytes(os.urandom(8192))

    def workload(rank, io):
        io.read_file(str(data), chunk=2048)

    Profiler(ProfilerOptions(mode="fleet", nranks=2,
                             spool_dir=spool)).run(workload)
    wh = str(tmp_path / "wh")
    assert main(["compact", spool, wh, "--run", "cap",
                 "--slice-s", "none"]) == 0
    out1 = capsys.readouterr().out
    assert "compacted" in out1 and "cap" in out1
    assert main(["stats", wh]) == 0
    out2 = capsys.readouterr().out
    assert "cap" in out2 and "2" in out2
    assert main(["query", wh, "--by", "op", "--op", "read"]) == 0
    out3 = capsys.readouterr().out
    assert "read" in out3 and "scan:" in out3
    # the aggregate table carries real numbers
    line = next(ln for ln in out3.splitlines()
                if ln.startswith("read"))
    assert int(line.split()[1]) > 0


def test_manifest_is_valid_json_and_atomic(tmp_path):
    with ArchiveWriter(str(tmp_path), run="r", slice_s=None) as w:
        w.add_batch(_cols(10), rank=0)
    mpath = tmp_path / "r" / "manifest.json"
    doc = json.loads(mpath.read_text())
    assert doc["version"] == 1 and len(doc["partitions"]) == 1
    assert not list(tmp_path.glob("**/*.tmp"))
