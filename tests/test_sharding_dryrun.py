"""Sharding rules + a miniature multi-device dry-run.

The mini dry-run runs in a SUBPROCESS because the 8-placeholder-device
XLA flag must be set before jax initializes (the main pytest process
keeps 1 device, per the assignment)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.specs import params_struct


class FakeMesh:
    """Duck-typed mesh for spec tests (axis sizes only)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def devices(self):
        import numpy as np
        return np.empty((1,))


MESH = FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ["qwen2-7b", "llama-3.2-vision-90b",
                                  "dbrx-132b", "mamba2-370m",
                                  "zamba2-1.2b", "whisper-tiny"])
def test_param_specs_are_divisible(arch):
    cfg = get_config(arch)
    pshape = params_struct(cfg)
    specs = shd.param_specs(cfg, pshape, MESH)

    def check(leaf, spec):
        assert len(spec) <= len(leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            n = shd.axis_size(MESH, entry)
            assert dim % n == 0, f"{arch}: {leaf.shape} vs {spec}"

    jax.tree.map(check, pshape, specs,
                 is_leaf=lambda x: hasattr(x, "shape"))


def test_head_indivisible_archs_replicate_heads():
    cfg = get_config("qwen2-7b")           # 28 heads, model axis 16
    pshape = params_struct(cfg)
    specs = shd.param_specs(cfg, pshape, MESH)
    wq_spec = specs["stack"]["layers"]["attn"]["wq"]
    assert wq_spec[2] is None              # head dim not sharded
    assert wq_spec[1] is not None          # but FSDP on d_model applies


def test_moe_expert_sharding_modes():
    import dataclasses
    cfg = get_config("dbrx-132b")
    pshape = params_struct(cfg)
    tp = shd.param_specs(cfg, pshape, MESH)
    w1 = tp["stack"]["layers"]["moe"]["w1"]
    assert w1[3] == "model"                # ffn sharded (tp mode)
    cfg_ep = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                 expert_sharding="ep"))
    ep = shd.param_specs(cfg_ep, pshape, MESH)
    w1e = ep["stack"]["layers"]["moe"]["w1"]
    assert w1e[1] == "model"               # expert dim sharded (ep mode)


def test_cache_specs_fall_back_to_sequence_parallel():
    from repro.configs import SHAPES_BY_NAME
    cfg = get_config("gemma3-12b")         # kv=8 < model 16 -> SP on seq
    specs = shd.cache_specs(cfg, SHAPES_BY_NAME["decode_32k"], MESH)
    assert specs["k"][2] is not None       # seq dim sharded
    assert specs["k"][3] is None
    cfg2 = get_config("zamba2-1.2b")       # kv=32 divisible -> head shard
    specs2 = shd.cache_specs(cfg2, SHAPES_BY_NAME["decode_32k"], MESH)
    assert specs2["shared_k"][3] == "model"


def test_shard_batch_noop_without_policy():
    x = jnp.ones((4, 8))
    assert shd.shard_batch(x) is x


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_config, SHAPES_BY_NAME
    from repro.distributed import sharding as shd
    from repro.launch.dryrun import build_cell
    import dataclasses

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("{arch}", reduced=True)
    shape = dataclasses.replace(SHAPES_BY_NAME["{shape}"],
                                seq_len=64, global_batch=8)
    shd.set_activation_axes(shd.batch_axes(mesh), mesh=mesh)
    jitted, args, extra = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jitted.lower(*args).compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    print(json.dumps({{"ok": True,
                       "temp": ma.temp_size_in_bytes,
                       "flops": ca.get("flops", 0.0)}}))
""")


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-7b", "train_4k"),
    ("dbrx-132b", "train_4k"),
    ("mamba2-370m", "decode_32k"),
    ("whisper-tiny", "prefill_32k"),
])
def test_mini_dryrun_compiles_on_8_devices(arch, shape):
    code = MINI_DRYRUN.format(arch=arch, shape=shape)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
