"""Elastic (mesh-agnostic) checkpoint restore + storage-tier model tests
+ DXT ring behaviour."""
import json
import subprocess
import sys
import textwrap
import time

import pytest


ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train.checkpoint import CheckpointManager

    tmp = sys.argv[1]
    mesh8 = jax.make_mesh((4, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh8 = NamedSharding(mesh8, P("data", "model"))
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8)
    mgr = CheckpointManager(tmp)
    mgr.save(1, {"w": w})

    # "restart" on a DIFFERENT mesh shape (elastic 8 -> 4 devices)
    mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2,
                          devices=jax.devices()[:4])
    sh4 = NamedSharding(mesh4, P("data", "model"))
    restored, _ = mgr.restore(
        1, target_tree={"w": jnp.zeros((8, 8))}, shardings={"w": sh4})
    ok = bool(jnp.all(restored["w"] == jnp.arange(64.0).reshape(8, 8)))
    n_shards = len(restored["w"].sharding.device_set)
    print(json.dumps({"ok": ok, "n_shards": n_shards}))
""")


def test_mesh_agnostic_restore_across_mesh_shapes(tmp_path):
    out = subprocess.run([sys.executable, "-c", ELASTIC, str(tmp_path)],
                         cwd=".", capture_output=True, text=True,
                         timeout=240)
    assert out.returncode == 0, out.stderr[-1500:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["n_shards"] == 4


def test_token_bucket_enforces_rate():
    from repro.data.tiers import TokenBucket
    tb = TokenBucket(10e6, burst=1e6)        # 10 MB/s
    t0 = time.perf_counter()
    for _ in range(10):
        tb.take(1_000_000)                    # 10 MB total
    dt = time.perf_counter() - t0
    assert 0.7 < dt < 2.0, dt                 # ~1 s at 10 MB/s


def test_hdd_seeks_serialize_but_lustre_seeks_do_not(tmp_path):
    from repro.data.tiers import StorageTier
    hdd = StorageTier("hdd", str(tmp_path / "hdd"),
                      bandwidth_bytes_s=1e9, open_latency_s=0.01,
                      seek_serialized=True)
    # alternate between two files -> every access is a head switch
    t0 = time.perf_counter()
    for i in range(10):
        hdd.note_access(f"/f{i % 2}")
    # serialized seeks turn into shared-bucket debt: ~10 x 10ms of device
    assert time.perf_counter() - t0 > 0.05

    lustre = StorageTier("l", str(tmp_path / "l"),
                         bandwidth_bytes_s=1e9, open_latency_s=0.01,
                         seek_serialized=False)
    import threading
    t0 = time.perf_counter()
    ts = [threading.Thread(target=lustre.note_access, args=(f"/f{i}",))
          for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # parallel metadata RTTs overlap
    assert time.perf_counter() - t0 < 0.06


def test_dxt_ring_drops_oldest_and_counts():
    from repro.core.dxt import DXTBuffer, Segment
    buf = DXTBuffer(capacity=64)
    for i in range(100):
        buf.add(Segment("POSIX", "/f", "read", 0, 1, float(i), float(i),
                        0))
    assert len(buf) <= 64
    assert buf.dropped > 0
    # newest segments survive
    times = [s.start for s in buf.window(0.0)]
    assert max(times) == 99.0


def test_tier_manager_longest_prefix_wins(tmp_path):
    from repro.data.tiers import StorageTier, TierManager
    outer = StorageTier("outer", str(tmp_path / "a"))
    inner = StorageTier("inner", str(tmp_path / "a" / "b"))
    tm = TierManager({"outer": outer, "inner": inner})
    assert tm.tier_of(str(tmp_path / "a" / "b" / "f")).name == "inner"
    assert tm.tier_of(str(tmp_path / "a" / "f")).name == "outer"
    assert tm.tier_of("/elsewhere/f") is None
