"""repro.fleet: simulated multi-rank collection, clock alignment,
cross-rank detectors, the wire format, and the extended ProfileServer
protocol (ISSUE 2 acceptance)."""
import json
import os
import socket
import time

import pytest

from repro.core import reset_runtime
from repro.core.advisor import StagingAdvisor
from repro.core.analysis import ModuleSummary, analyze
from repro.core.dxt import Segment
from repro.core.export import to_darshan_log
from repro.core.records import FileRecord
from repro.core.session import ProfileServer, control
from repro.data.tiers import TokenBucket
from repro.fleet import (CollectorServer, FleetCollector, RankReporter,
                         RankSlice, payloads, run_simulated_fleet)
from repro.fleet.detectors import (LoadImbalanceDetector,
                                   RankStragglerDetector,
                                   SharedFileContentionDetector)
from repro.insight.detectors import Finding
from repro.link import LINK_VERSION, WireError, decode, encode


def _make_files(root, rank, n, size):
    paths = []
    os.makedirs(str(root), exist_ok=True)
    for i in range(n):
        p = os.path.join(str(root), f"rank{rank}_{i:03d}.bin")
        with open(p, "wb") as f:
            f.write(b"x" * size)
        paths.append(p)
    return paths


def _detector_names(report):
    return sorted({f.detector for f in report.findings})


# ------------------------------------------------------------ wire format
def test_wire_roundtrip_report_payload():
    per_file = {"/d/a.bin": FileRecord("/d/a.bin",
                                       {"POSIX_READS": 3,
                                        "POSIX_BYTES_READ": 4096},
                                       {"POSIX_F_READ_TIME": 0.25}),
                "/d/b.bin": FileRecord("/d/b.bin", {"POSIX_OPENS": 1}, {})}
    rep = analyze(per_file, {}, elapsed_s=1.5, stat_sizes=False)
    rep.segments = [Segment("POSIX", "/d/a.bin", "read", 0, 4096,
                            0.1, 0.2, 7)]
    rep.findings = [Finding("small-file-storm", "Small-file storm", 0.8,
                            (0.0, 1.0), {"opens": 64.0}, "stage", rank=2)]
    rep.file_sizes = {"/d/a.bin": 4096}

    line = payloads.encode_report(2, rep, nprocs=4, clock_offset_s=-3.25,
                                  clock_rtt_s=1e-4)
    msg = decode(line)
    assert (msg.v, msg.kind, msg.rank) == (LINK_VERSION, "report", 2)
    back = payloads.decode_records(msg.payload["posix"])
    assert back["/d/a.bin"].counters == per_file["/d/a.bin"].counters
    assert back["/d/a.bin"].fcounters == per_file["/d/a.bin"].fcounters
    assert back["/d/b.bin"].counters == per_file["/d/b.bin"].counters
    # segments ride columnar by default: one object of parallel arrays
    cols = payloads.decode_segments_columns(
        msg.payload["segments_columns"])
    assert cols.to_rows() == rep.segments
    assert payloads.decode_report_segments(msg.payload).to_rows() \
        == rep.segments
    founds = payloads.decode_findings(msg.payload["findings"])
    assert founds == rep.findings
    assert msg.payload["clock"]["offset_s"] == -3.25
    assert msg.payload["file_sizes"] == {"/d/a.bin": 4096}

    # the legacy per-row shape remains selectable and decodes the same
    legacy_line = payloads.encode_report(2, rep, nprocs=4,
                                         segments_wire="rows")
    legacy_msg = decode(legacy_line)
    assert "segments_columns" not in legacy_msg.payload
    segs = payloads.decode_segments(legacy_msg.payload["segments"])
    assert segs == rep.segments
    assert payloads.decode_report_segments(legacy_msg.payload).to_rows() \
        == rep.segments


def test_wire_rejects_garbage_and_future_versions():
    with pytest.raises(WireError):
        decode("not json at all {")
    with pytest.raises(WireError):
        decode(json.dumps({"v": LINK_VERSION + 1,
                           "kind": "report", "rank": 0,
                           "payload": {}}))
    with pytest.raises(WireError):
        decode(json.dumps({"v": 1, "kind": "nope", "rank": 0,
                           "payload": {}}))
    with pytest.raises(WireError):
        encode("nope", 0, {})


def test_fleet_wire_shim_warns_and_forwards():
    """The moved repro.fleet.wire names keep working one release
    longer, loudly."""
    import repro.fleet.wire as legacy
    with pytest.warns(DeprecationWarning, match="repro.link"):
        assert legacy.WIRE_VERSION == LINK_VERSION
    with pytest.warns(DeprecationWarning):
        msg = legacy.decode(encode("bye", 3, {}))
    assert (msg.kind, msg.rank) == ("bye", 3)
    with pytest.warns(DeprecationWarning, match="payloads"):
        assert legacy.encode_hello(0, 2).startswith("{")
    with pytest.raises(AttributeError):
        legacy.never_existed


# ------------------------------------------------- simulated fleet e2e
def test_simulated_4rank_merged_counters_equal_per_rank_sums(tmp_path):
    files = {r: _make_files(tmp_path, r, 6, 32768) for r in range(4)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=8192)

    coll = FleetCollector()
    rep = run_simulated_fleet(4, workload, collector=coll)
    assert rep.nprocs == 4
    assert sorted(rep.ranks) == [0, 1, 2, 3]
    assert coll.stats["reports"] == 4
    # global rollup == per-rank sums, and equals ground truth
    assert rep.posix.reads == sum(s.posix.reads for s in rep.ranks.values())
    assert rep.posix.bytes_read == 4 * 6 * 32768
    assert rep.posix.opens == sum(s.posix.opens for s in rep.ranks.values())
    for i in range(10):
        assert rep.posix.read_size_hist[i] == sum(
            s.posix.read_size_hist[i] for s in rep.ranks.values())
    # merged chrome trace: one pid per rank
    trace = rep.to_chrome_trace(str(tmp_path / "fleet.json"))
    pids = {e["pid"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert pids == {"rank 0", "rank 1", "rank 2", "rank 3"}
    assert (tmp_path / "fleet.json").exists()
    # merged timeline is globally ordered
    merged = rep.merged_segments()
    assert [s.start for _, s in merged] == sorted(s.start
                                                  for _, s in merged)


def test_clock_handshake_recovers_injected_skew(tmp_path):
    files = {r: _make_files(tmp_path, r, 4, 16384) for r in range(4)}
    skews = [0.0, 5.0, 10.0, 15.0]

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p)

    rep = run_simulated_fleet(4, workload, clock_skew_s=skews,
                              handshake_rounds=5)
    for r, s in rep.ranks.items():
        # offset must cancel the injected skew (in-process RTT is ~µs)
        assert s.clock_offset_s == pytest.approx(-skews[r], abs=0.05)
        # aligned segments: monotone per rank, on the collector clock
        starts = [seg.start for seg in s.segments]
        assert starts == sorted(starts)
        assert all(-0.1 <= t < 5.0 for t in starts), \
            f"rank {r} not aligned: {starts[:3]}"
    # and therefore the fleet window is tight, not skew-spread
    assert rep.window[1] - rep.window[0] < 5.0


def test_rank_straggler_fires_on_throttled_rank(tmp_path):
    files = {r: _make_files(tmp_path, r, 6, 65536) for r in range(4)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=16384)

    # rank 2 reads through a 1 MB/s tier (data/tiers TokenBucket with a
    # small burst so the throttle actually engages on ~384 KiB)
    bucket = TokenBucket(1e6, burst=16384)
    rep = run_simulated_fleet(4, workload, throttles={2: bucket.take})
    stragglers = [f for f in rep.findings if f.detector == "rank-straggler"]
    assert len(stragglers) == 1
    f = stragglers[0]
    assert f.rank == 2
    assert f.evidence["straggler_rank"] == 2
    assert f.evidence["ratio"] >= RankStragglerDetector.MIN_RATIO
    assert f.severity > 0
    assert "rank 2" in f.recommendation.lower()
    # balanced volume => no load-imbalance false positive
    assert "load-imbalance" not in _detector_names(rep)


def test_balanced_fleet_raises_no_cross_rank_findings(tmp_path):
    files = {r: _make_files(tmp_path, r, 4, 32768) for r in range(4)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p)

    rep = run_simulated_fleet(4, workload)
    assert "rank-straggler" not in _detector_names(rep)
    assert "load-imbalance" not in _detector_names(rep)


# -------------------------------------------------- detector unit tests
def _slice_with(rank, bytes_read=0, read_time_s=0.0, segments=()):
    s = RankSlice(rank=rank)
    s.posix = ModuleSummary("POSIX")
    s.posix.bytes_read = bytes_read
    s.posix.read_time_s = read_time_s
    s.posix.reads = max(1, bytes_read // 4096)
    s.segments = list(segments)
    return s


def test_load_imbalance_detector_flags_heavy_rank():
    det = LoadImbalanceDetector()
    ranks = {0: _slice_with(0, bytes_read=8 << 20),
             1: _slice_with(1, bytes_read=1 << 20),
             2: _slice_with(2, bytes_read=1 << 20),
             3: _slice_with(3, bytes_read=1 << 20)}
    out = det.check(ranks)
    assert len(out) == 1 and out[0].rank == 0
    assert out[0].evidence["ratio"] >= det.MIN_RATIO
    # balanced -> nothing
    ranks = {r: _slice_with(r, bytes_read=4 << 20) for r in range(4)}
    assert det.check(ranks) == []
    # tiny volume -> nothing
    ranks = {0: _slice_with(0, bytes_read=8000),
             1: _slice_with(1, bytes_read=100)}
    assert det.check(ranks) == []


def test_shared_file_contention_detector_needs_overlap():
    det = SharedFileContentionDetector()

    def seg(rank_t0, dur, path="/shared/data.bin"):
        return Segment("POSIX", path, "read", 0, 4096,
                       rank_t0, rank_t0 + dur, 1)

    # two ranks inside the same file at the same time
    ranks = {0: _slice_with(0, segments=[seg(0.0, 0.10)]),
             1: _slice_with(1, segments=[seg(0.02, 0.10)])}
    out = det.check(ranks)
    assert len(out) == 1
    f = out[0]
    assert f.detector == "shared-file-contention"
    assert f.rank is None                      # collective pathology
    assert f.evidence["path_ranks"] == 2
    assert f.evidence["overlap_frac"] > 0.5
    # same file, disjoint times -> no contention
    ranks = {0: _slice_with(0, segments=[seg(0.0, 0.05)]),
             1: _slice_with(1, segments=[seg(0.5, 0.05)])}
    assert det.check(ranks) == []
    # overlap on DIFFERENT files -> no contention
    ranks = {0: _slice_with(0, segments=[seg(0.0, 0.1, "/a")]),
             1: _slice_with(1, segments=[seg(0.0, 0.1, "/b")])}
    assert det.check(ranks) == []


def test_rank_straggler_detector_ignores_microsecond_fleets():
    det = RankStragglerDetector()
    ranks = {0: _slice_with(0, read_time_s=8e-5),
             1: _slice_with(1, read_time_s=1e-5),
             2: _slice_with(2, read_time_s=1e-5)}
    assert det.check(ranks) == []              # all cache-hit noise
    ranks = {0: _slice_with(0, read_time_s=0.8),
             1: _slice_with(1, read_time_s=0.1),
             2: _slice_with(2, read_time_s=0.1)}
    out = det.check(ranks)
    assert len(out) == 1 and out[0].rank == 0


# ------------------------------------------------ fleet staging plan
def test_fleet_staging_plan_prefers_files_shared_by_more_ranks():
    shared, private = "/d/shared.bin", "/d/private.bin"

    def slice_reading(rank, paths):
        s = RankSlice(rank=rank)
        s.per_file = {p: FileRecord(p, {"POSIX_READS": 2}) for p in paths}
        s.file_sizes = {p: 1 << 20 for p in paths}
        return s

    ranks = {r: slice_reading(r, [shared] if r else [shared, private])
             for r in range(4)}
    from repro.fleet.report import FleetReport, merge_summaries
    fr = FleetReport(nprocs=4, ranks=ranks,
                     posix=ModuleSummary("POSIX"),
                     stdio=ModuleSummary("STDIO"), findings=[])
    # capacity for exactly one file: the 4-reader file must win
    plan = StagingAdvisor(size_threshold=2 << 20,
                          capacity_bytes=1 << 20).fleet_plan(fr)
    assert plan.total_files == 1
    assert plan.files[0][0] == shared
    # unconstrained: both staged, dataset is the union (2 files)
    plan = StagingAdvisor(size_threshold=2 << 20).fleet_plan(fr)
    assert plan.total_files == 2
    assert plan.dataset_files == 2


# ---------------------------------------- ProfileServer fleet protocol
def test_profile_server_stop_reply_contains_findings(tmp_path):
    paths = _make_files(tmp_path, 0, 48, 1024)   # created BEFORE profiling
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt, insight=True)
    try:
        assert control(srv.port, "start") == "ok"
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 4096)
            os.close(fd)
        stop = control(srv.port, "stop", parse=True)
        assert "findings" in stop
        assert "small-file-storm" in [f["detector"]
                                      for f in stop["findings"]]
        assert stop["reads"] >= 48
        # findings verb re-serves the last window's findings
        again = control(srv.port, "findings", parse=True)
        assert again["findings"] == stop["findings"]
    finally:
        srv.close()


def test_profile_server_legacy_clients_still_work(tmp_path):
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt)
    try:
        # unparsed string replies, exactly as before
        assert control(srv.port, "status") == "active=False"
        assert control(srv.port, "start") == "ok"
        raw = control(srv.port, "stop")
        assert "posix_bandwidth_mb_s" in json.loads(raw)
        assert control(srv.port, "bogus") == "unknown"
        # a client that sends its command with no trailing newline
        with socket.create_connection(("127.0.0.1", srv.port)) as s:
            s.sendall(b"status")
            s.shutdown(socket.SHUT_WR)
            assert s.recv(4096) == b"active=False\n"
    finally:
        srv.close()


def test_profile_server_multi_command_single_connection(tmp_path):
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt)
    try:
        with socket.create_connection(("127.0.0.1", srv.port)) as s:
            s.sendall(b"status\nstart\nstatus\n")
            deadline = time.time() + 5
            buf = b""
            while buf.count(b"\n") < 3 and time.time() < deadline:
                buf += s.recv(4096)
        assert buf.decode().splitlines() == ["active=False", "ok",
                                             "active=True"]
    finally:
        srv.close()


def test_profile_server_report_verb_feeds_collector(tmp_path):
    paths = _make_files(tmp_path, 0, 8, 8192)
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt, rank=3, nprocs=8)
    try:
        control(srv.port, "start")
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 16384)
            os.close(fd)
        control(srv.port, "stop")
        line = control(srv.port, "report")     # far beyond 256 bytes
        assert len(line) > 256
        clk = control(srv.port, "clock 0.0", parse=True)
        assert "t" in clk and "wall" in clk
        coll = FleetCollector()
        assert coll.ingest_line(line) == "ok"
        fleet = coll.report()
        assert fleet.ranks[3].posix.bytes_read == 8 * 8192
        assert fleet.nprocs == 8
    finally:
        srv.close()


def test_collector_server_socket_roundtrip(tmp_path):
    files = {r: _make_files(tmp_path, r, 4, 16384) for r in range(2)}
    with CollectorServer() as cs:
        for r in range(2):
            from repro.core.runtime import DarshanRuntime
            from repro.fleet.harness import RankIO
            rep = RankReporter(r, nprocs=2, runtime=DarshanRuntime(),
                               auto_attach=False)
            io = RankIO(rep.rt)
            with rep:
                for p in files[r]:
                    io.read_file(p)
            rep.ship_socket("127.0.0.1", cs.port)
        fleet = cs.collector.report()
    assert sorted(fleet.ranks) == [0, 1]
    assert fleet.posix.bytes_read == 2 * 4 * 16384
    assert all(abs(s.clock_offset_s) < 1.0 for s in fleet.ranks.values())
    assert cs.collector.stats["reports"] == 2
    assert cs.collector.stats["errors"] == 0


def test_nested_sessions_do_not_blind_outer_window(tmp_path):
    """A fleet RankReporter spans the whole run while a StepCallback
    window opens and closes inside it: the inner stop must restore (not
    clear) runtime recording, or the outer window goes blind."""
    from repro.core import ProfileSession
    paths = _make_files(tmp_path, 0, 2, 4096)
    rt = reset_runtime()
    outer = ProfileSession(rt)
    outer.start()
    inner = ProfileSession(rt, auto_attach=False)
    inner.start()
    fd = os.open(paths[0], os.O_RDONLY)
    os.read(fd, 4096)
    os.close(fd)
    inner.stop()
    assert rt.enabled                     # restored, not cleared
    fd = os.open(paths[1], os.O_RDONLY)   # after the inner window
    os.read(fd, 4096)
    os.close(fd)
    rep = outer.stop()
    assert not rt.enabled
    assert rep.posix.reads == 2           # outer saw BOTH reads


def test_profile_server_replies_to_newline_less_idle_client():
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt)
    try:
        # legacy client: no trailing newline, write side kept open
        with socket.create_connection(("127.0.0.1", srv.port)) as s:
            s.settimeout(5)
            s.sendall(b"status")
            assert s.recv(4096) == b"active=False\n"
    finally:
        srv.close()


# ------------------------------------------------------ darshan log rank
def test_darshan_log_emits_actual_rank_and_header_block():
    per_file = {"/d/x.bin": FileRecord("/d/x.bin", {"POSIX_READS": 5})}
    rep = analyze(per_file, {}, elapsed_s=1.0, stat_sizes=False)
    text = to_darshan_log(rep, rank=7, exe="train.py --epochs 3", nprocs=16)
    assert "# exe: train.py --epochs 3" in text
    assert "# nprocs: 16" in text
    assert "POSIX\t7\t" in text
    assert "POSIX\t0\t" not in text


def test_fleet_darshan_log_one_block_per_rank(tmp_path):
    files = {r: _make_files(tmp_path, r, 2, 4096) for r in range(3)}

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p)

    rep = run_simulated_fleet(3, workload)
    text = rep.to_darshan_log(exe="fleet_demo.py")
    assert "# nprocs: 3" in text
    for r in range(3):
        assert f"POSIX\t{r}\t" in text
    # every record line carries the rank that produced it
    for line in text.splitlines():
        if line.startswith("POSIX\t"):
            rank = int(line.split("\t")[1])
            fpath = line.split("\t")[-1]
            assert f"rank{rank}_" in os.path.basename(fpath)


# ------------------------------------------------------- trainer hook
def test_trainer_attaches_rank_reporter(tmp_path):
    jax = pytest.importorskip("jax")
    import numpy as np
    from repro.configs import get_config
    from repro.train.trainer import Trainer, TrainerConfig

    def batches():
        rng = np.random.default_rng(0)
        while True:
            yield rng.integers(0, 128, (2, 33)).astype(np.int32)

    reset_runtime()
    cfg = get_config("qwen1.5-4b", reduced=True)
    tcfg = TrainerConfig(steps=2, checkpoint_every=2, log_every=1,
                         checkpoint_dir=str(tmp_path / "ck"),
                         checkpoint_async=False)
    reporter = RankReporter(rank=0, nprocs=1)
    out = Trainer(cfg, tcfg, batches(), fleet_reporter=reporter).run()
    assert out["final_step"] == 2
    rep = out["rank_report"]
    assert rep is not None
    # the checkpoint write landed inside the rank's profiled window
    assert rep.stdio.bytes_written > 0
    # and the window ships through the wire like any other rank
    coll = FleetCollector()
    reporter.ship(coll.ingest_line)
    slice0 = coll.report().ranks[0]
    assert slice0.stdio.bytes_written == rep.stdio.bytes_written
    assert slice0.elapsed_s > 0


# ----------------------------------------- columnar wire equivalence
def _recorded_report(rank):
    """A deterministic SessionReport window (fixed counters, segments,
    findings) — the same recording ships over every wire shape."""
    per_file = {}
    for i in range(3):
        p = f"/data/r{rank}/f{i}.bin"
        per_file[p] = FileRecord(p, {"POSIX_OPENS": 1, "POSIX_READS": 4,
                                     "POSIX_BYTES_READ": 1 << 18},
                                 {"POSIX_F_READ_TIME": 0.01 * (i + 1)})
    rep = analyze(per_file, {}, elapsed_s=1.25, stat_sizes=False)
    rep.file_sizes = {p: 1 << 18 for p in per_file}
    paths = sorted(per_file)
    rep.segments = [Segment("POSIX", paths[i % 3], "read",
                            i * 4096, 4096, 0.05 * i, 0.05 * i + 0.01,
                            rank + 1)
                    for i in range(12)]
    rep.findings = [Finding("small-file-storm", "Small-file storm",
                            0.5 + 0.1 * rank, (0.0, 1.0),
                            {"opens": 3.0}, "stage", rank=rank)]
    return rep


def _ship_fixed(transport, rank, wire):
    """hello + report (fixed clock offset — alignment must not depend
    on handshake timing for this comparison) + bye."""
    transport(payloads.encode_hello(rank, 2))
    transport(payloads.encode_report(rank, _recorded_report(rank),
                                     nprocs=2, clock_offset_s=0.125,
                                     clock_rtt_s=1e-4,
                                     segments_wire=wire))
    transport(encode("bye", rank, {}))


def _collect_over(transport_kind, wire, tmp_path):
    from repro.link import SpoolReader, SpoolTransport, TcpTransport
    coll = FleetCollector()
    if transport_kind == "tcp":
        server = CollectorServer(coll, idle_timeout_s=1.0)
        try:
            for rank in range(2):
                with TcpTransport("127.0.0.1", server.port) as t:
                    _ship_fixed(t, rank, wire)
        finally:
            server.close()
    else:
        spool = str(tmp_path / f"spool_{wire}")
        for rank in range(2):
            with SpoolTransport(spool, name=f"rank{rank:05d}") as t:
                _ship_fixed(t, rank, wire)
        coll.ingest_spool(SpoolReader(spool))
    return coll.report()


@pytest.mark.parametrize("transport_kind", ["tcp", "spool"])
def test_columns_wire_reproduces_row_wire_fleet_report(tmp_path,
                                                       transport_kind):
    """ISSUE 5 acceptance: the same recorded windows shipped as
    segments_columns payloads and as legacy per-row payloads produce
    byte-for-byte the same FleetReport counters, findings, and aligned
    segments — over tcp and spool alike."""
    cols_fleet = _collect_over(transport_kind, "columns", tmp_path)
    rows_fleet = _collect_over(transport_kind, "rows", tmp_path)

    assert cols_fleet.posix == rows_fleet.posix
    assert cols_fleet.stdio == rows_fleet.stdio
    assert cols_fleet.findings == rows_fleet.findings
    assert cols_fleet.nprocs == rows_fleet.nprocs
    assert cols_fleet.window == rows_fleet.window
    for r in (0, 1):
        a, b = cols_fleet.ranks[r], rows_fleet.ranks[r]
        assert list(a.segments) == list(b.segments)
        assert a.per_file == b.per_file
        assert a.clock_offset_s == b.clock_offset_s == 0.125
    # the panel payloads agree wholesale (collector transfer stats and
    # the self-telemetry rollup are the only legitimate differences:
    # the wires have different byte counts and ingest timings)
    da, db = cols_fleet.to_dict(), rows_fleet.to_dict()
    da.pop("collector"), db.pop("collector")
    ma, mb = da.pop("metrics"), db.pop("metrics")
    assert da == db
    # ...and even there, only byte/timing metrics may differ
    for volatile in ("collector.bytes",):
        ma["counters"].pop(volatile), mb["counters"].pop(volatile)
    assert ma["counters"] == mb["counters"]
    assert set(ma["gauges"]) == set(mb["gauges"])
    # and the columnar wire is the smaller one
    cols_line = payloads.encode_report(0, _recorded_report(0), nprocs=2)
    rows_line = payloads.encode_report(0, _recorded_report(0), nprocs=2,
                                       segments_wire="rows")
    assert len(cols_line) < len(rows_line)


# -------------------------------------------------- spool clock (mtime)
def test_spool_mtime_handshake_recovers_skew(tmp_path):
    """Spool-only fleets get aligned timelines too: the file-mtime
    handshake recovers an injected 6 s clock skew (within filesystem
    mtime resolution)."""
    from repro.fleet.harness import simulate_fleet
    from repro.link import SpoolTransport
    files = _make_files(tmp_path / "d", 0, 4, 16384)

    def workload(rank, io):
        for p in files:
            io.read_file(p, chunk=8192)

    spool = str(tmp_path / "spool")
    coll = FleetCollector()
    simulate_fleet(2, workload, coll, clock_skew_s=[0.0, 6.0],
                   make_transport=lambda r: SpoolTransport(
                       spool, name=f"rank{r:05d}"),
                   collect=False)
    coll.ingest_spool(spool)
    fleet = coll.report()
    rel = fleet.ranks[1].clock_offset_s - fleet.ranks[0].clock_offset_s
    assert rel == pytest.approx(-6.0, abs=2.0)
    # aligned: both ranks' segments land in the same real-time window
    s0, s1 = fleet.ranks[0].segments, fleet.ranks[1].segments
    assert abs(s0[0].start - s1[0].start) < 2.0
