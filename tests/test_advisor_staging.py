"""Advisor + staging tests: plan properties, autotuning, tier behaviour."""
import os

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.advisor import (StagingAdvisor, ThreadAutotuneAdvisor,
                                workload_character)
from repro.core.analysis import analyze
from repro.core.records import FileRecord
from repro.core.staging import StagingManager

SETTINGS = dict(deadline=None, max_examples=30)


def _report_from_sizes(sizes: dict):
    recs = {p: FileRecord(p, {"POSIX_READS": 1, "POSIX_OPENS": 1,
                              "POSIX_BYTES_READ": s}) for p, s in
            sizes.items()}
    rep = analyze(recs, {}, elapsed_s=1.0, stat_sizes=False)
    rep.file_sizes = dict(sizes)
    return rep


@given(st.dictionaries(st.integers(0, 200).map(lambda i: f"/d/f{i}"),
                       st.integers(1, 8 * 2**20), min_size=1, max_size=50),
       st.integers(1, 4 * 2**20))
@settings(**SETTINGS)
def test_plan_respects_threshold_and_prefers_smallest(sizes, threshold):
    plan = StagingAdvisor(size_threshold=threshold).plan(
        _report_from_sizes(sizes))
    chosen = dict(plan.files)
    assert all(s < threshold for s in chosen.values())
    # every unchosen under-threshold file must be >= the largest chosen
    if chosen:
        biggest = max(chosen.values())
        for p, s in sizes.items():
            if p not in chosen and s < threshold:
                assert s >= biggest


@given(st.dictionaries(st.integers(0, 100).map(lambda i: f"/d/f{i}"),
                       st.integers(1, 2**20), min_size=1, max_size=40),
       st.integers(1, 2**21))
@settings(**SETTINGS)
def test_plan_respects_capacity_budget(sizes, capacity):
    plan = StagingAdvisor(size_threshold=2**22,
                          capacity_bytes=capacity).plan(
        _report_from_sizes(sizes))
    assert plan.total_bytes <= capacity


def test_plan_summary_mirrors_paper_fractions():
    sizes = {f"/d/small{i}": 300_000 for i in range(40)}
    sizes.update({f"/d/big{i}": 4_000_000 for i in range(60)})
    plan = StagingAdvisor(size_threshold=2_000_000).plan(
        _report_from_sizes(sizes))
    assert plan.total_files == 40
    assert plan.files_frac == pytest.approx(0.4)
    assert plan.bytes_frac == pytest.approx(
        40 * 300_000 / (40 * 300_000 + 60 * 4_000_000))


def test_autotune_scales_up_on_gains_and_backs_off_on_regression():
    adv = ThreadAutotuneAdvisor(start=1)
    a = adv.observe(1, 10.0)
    assert a.threads > 1                     # explore upward
    b = adv.observe(a.threads, 40.0)         # big gain -> continue
    assert b.threads > a.threads
    c = adv.observe(b.threads, 20.0)         # regression -> back off
    assert c.threads == a.threads
    assert adv.best() == a.threads


def test_staging_manager_stage_and_resolve(tmp_path):
    src = tmp_path / "slow"
    src.mkdir()
    files = []
    for i in range(3):
        f = src / f"{i}.bin"
        f.write_bytes(bytes([i]) * 100)
        files.append((str(f), 100))
    from repro.core.advisor import StagingPlan
    plan = StagingPlan(files=tuple(files), total_bytes=300, total_files=3,
                       dataset_bytes=300, dataset_files=3,
                       size_threshold=1000)
    mgr = StagingManager(str(tmp_path / "fast"))
    res = mgr.stage(plan)
    assert res.bytes_copied == 300
    for path, _ in files:
        staged = mgr.resolve(path)
        assert staged != path and os.path.exists(staged)
        assert open(staged, "rb").read() == open(path, "rb").read()
    mgr.unstage_all()
    assert mgr.resolve(files[0][0]) == files[0][0]


def test_workload_character():
    small = _report_from_sizes({f"/f{i}": 90_000 for i in range(10)})
    large = _report_from_sizes({f"/f{i}": 4_000_000 for i in range(10)})
    assert workload_character(small) == "small-file"
    assert workload_character(large) == "large-file"
