"""Optimizer math + state-spec tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state, opt_state_specs)


def test_adamw_matches_reference_math():
    ocfg = OptimizerConfig(name="adamw", lr=0.1, b1=0.9, b2=0.99,
                           eps=1e-8, weight_decay=0.0, grad_clip=0.0,
                           warmup_steps=1)
    params = {"w": jnp.ones((3,), jnp.float32)}
    grads = {"w": jnp.asarray([0.5, -1.0, 2.0])}
    state = init_opt_state(ocfg, params)
    new_p, state, _ = apply_updates(ocfg, params, grads, state)
    g = np.asarray([0.5, -1.0, 2.0])
    m = 0.1 * g
    v = 0.01 * g**2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expected = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expected, rtol=1e-5)


def test_grad_clip_bounds_update():
    ocfg = OptimizerConfig(name="adamw", lr=1.0, grad_clip=1.0,
                           weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    huge = {"w": jnp.full((4,), 1e6)}
    state = init_opt_state(ocfg, params)
    _, _, stats = apply_updates(ocfg, params, huge, state)
    assert float(stats["grad_norm"]) > 1e5   # reported pre-clip


def test_warmup_schedule():
    ocfg = OptimizerConfig(name="adamw", lr=1.0, warmup_steps=10)
    params = {"w": jnp.zeros((2,))}
    state = init_opt_state(ocfg, params)
    _, state, stats = apply_updates(ocfg, params, {"w": jnp.ones((2,))},
                                    state)
    assert float(stats["lr"]) == pytest.approx(0.1)


def test_adafactor_factored_state_shapes():
    ocfg = OptimizerConfig(name="adafactor", b1=0.0, factored_threshold=128)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 8)),
              "vec": jnp.zeros((300,))}
    state = init_opt_state(ocfg, params)
    leaves = state["leaves"]
    assert leaves["big"]["v_row"].shape == (256,)
    assert leaves["big"]["v_col"].shape == (512,)
    assert leaves["small"]["v"].shape == (4, 8)
    assert leaves["vec"]["v"].shape == (300,)


def test_adafactor_reduces_loss_direction():
    ocfg = OptimizerConfig(name="adafactor", lr=0.1, b1=0.0,
                           weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.full((256, 256), 2.0)}
    state = init_opt_state(ocfg, params)
    # grad of 0.5*w^2 = w -> update must move towards 0
    for _ in range(3):
        params, state, _ = apply_updates(ocfg, params, {"w": params["w"]},
                                         state)
    assert float(jnp.mean(params["w"])) < 2.0


def test_opt_state_specs_follow_param_specs():
    ocfg = OptimizerConfig(name="adafactor", b1=0.0, factored_threshold=128)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 8))}
    pspecs = {"big": P("data", "model"), "small": P(None, None)}
    specs = opt_state_specs(ocfg, pspecs, params)
    assert specs["leaves"]["big"]["v_row"] == P("data")
    assert specs["leaves"]["big"]["v_col"] == P("model")
    assert specs["leaves"]["small"]["v"] == P(None, None)
    assert specs["step"] == P()

    ocfg2 = OptimizerConfig(name="adamw")
    specs2 = opt_state_specs(ocfg2, pspecs, params)
    assert specs2["leaves"]["big"]["m"] == P("data", "model")
