"""repro.link: the typed message/transport layer (ISSUE 4 acceptance).

Covers the versioned codec (field-naming errors with line snippets,
version negotiation), the Endpoint verb dispatch with registry-resolved
extensions, a transport conformance suite run against ALL THREE
``Transport`` implementations with the same assertions, the dual-stack
ProfileServer, and the real multi-process fleet path
(``launch="spawn"`` over tcp and spool) matching a simulated run."""
import json
import os
import socket
import time

import pytest

from repro.core import reset_runtime
from repro.core.analysis import analyze
from repro.core.dxt import Segment
from repro.core.records import FileRecord
from repro.core.runtime import DarshanRuntime
from repro.core.session import ProfileServer, control
from repro.fleet import (CollectorServer, FleetCollector, RankReporter,
                         payloads)
from repro.insight.detectors import Finding
from repro.link import (KINDS, LINK_VERSION, CallableTransport, Endpoint,
                        LoopbackTransport, Message, SpoolReader,
                        SpoolTransport, TcpTransport, WireError,
                        as_transport, check_hello, decode, encode)
from repro.profiler import get_registry, register_verb


# ---------------------------------------------------------------- codec
def test_codec_roundtrip_all_builtin_kinds():
    for kind in KINDS:
        line = encode(kind, 7, {"x": 1, "s": "é", "f": 0.25})
        msg = decode(line)
        assert (msg.kind, msg.rank, msg.v) == (kind, 7, LINK_VERSION)
        assert msg.payload == {"x": 1, "s": "é", "f": 0.25}
        assert msg.encode() == line


def test_codec_errors_name_field_and_quote_snippet():
    with pytest.raises(WireError, match="not JSON") as e:
        decode("not json at all {")
    assert "not json at all {" in str(e.value)

    long_line = json.dumps({"v": 1, "kind": "hello", "rank": -3,
                            "payload": {"pad": "x" * 500}})
    with pytest.raises(WireError, match="'rank'") as e:
        decode(long_line)
    # the snippet is truncated, not the whole 500-byte line
    assert "..." in str(e.value) and len(str(e.value)) < 250

    with pytest.raises(WireError, match="'payload'"):
        decode(json.dumps({"v": 1, "kind": "hello", "rank": 0,
                           "payload": [1, 2]}))
    with pytest.raises(WireError, match="'v'"):
        decode(json.dumps({"v": "one", "kind": "hello", "rank": 0,
                           "payload": {}}))
    with pytest.raises(WireError, match="'kind'"):
        decode(json.dumps({"v": 1, "kind": "nope", "rank": 0,
                           "payload": {}}))
    with pytest.raises(WireError, match="missing field 'kind'"):
        decode(json.dumps({"v": 1, "rank": 0, "payload": {}}))


def test_codec_rejects_future_versions_loudly():
    line = json.dumps({"v": LINK_VERSION + 1, "kind": "report", "rank": 0,
                       "payload": {}})
    with pytest.raises(WireError, match="unsupported wire version"):
        decode(line)
    with pytest.raises(WireError, match="unknown kind"):
        encode("nope", 0, {})


def test_check_hello_negotiates_and_rejects():
    assert check_hello({"link_v": LINK_VERSION}) == LINK_VERSION
    assert check_hello({}) == 1                      # pre-negotiation peer
    # a newer peer negotiates DOWN to what we speak
    assert check_hello({"link_v": 99}) == LINK_VERSION
    # ...unless it requires more than we have: loud mismatch
    with pytest.raises(WireError, match="requires link protocol"):
        check_hello({"link_v": 99, "link_min_v": 99})
    with pytest.raises(WireError, match="link_v"):
        check_hello({"link_v": "new"})


# ------------------------------------------------------------- endpoint
def test_endpoint_dispatches_local_handlers_and_default():
    seen = []
    ep = Endpoint(context=seen)

    @ep.on("status")
    def _status(endpoint, msg):
        endpoint.context.append(msg.rank)
        return msg.reply("ok", {"n": len(endpoint.context)})

    reply = decode(ep.dispatch_line(encode("status", 5)))
    assert reply.kind == "ok" and reply.payload == {"n": 1}
    assert seen == [5]
    # built-in kind without a handler -> error reply, not an exception
    err = decode(ep.dispatch_line(encode("bye", 0)))
    assert err.kind == "error" and "bye" in err.payload["error"]


def test_register_verb_extends_codec_and_every_endpoint():
    calls = []

    def handler(endpoint, msg):
        calls.append((endpoint.context, msg.payload["x"]))
        return msg.reply("ok")

    register_verb("test-custom-kind", handler)
    try:
        line = encode("test-custom-kind", 2, {"x": 41})   # codec accepts
        assert decode(line).kind == "test-custom-kind"
        ep = Endpoint(context="ctx")
        assert decode(ep.dispatch_line(line)).kind == "ok"
        assert calls == [("ctx", 41)]
        # endpoint-local handlers take precedence over the registry
        ep.register("test-custom-kind",
                    lambda endpoint, msg: msg.reply("ok", {"local": True}))
        assert decode(ep.dispatch_line(line)).payload == {"local": True}
    finally:
        get_registry("verb").unregister("test-custom-kind")
    with pytest.raises(WireError):
        encode("test-custom-kind", 0, {})      # gone after unregister


def test_register_verb_rejects_builtin_kinds():
    from repro.profiler import RegistryError
    with pytest.raises(RegistryError, match="built-in"):
        register_verb("report", lambda endpoint, msg: "ok")


# ------------------------------------------- transport conformance suite
def _synth_report(rank, n_files=4, reads_per_file=3):
    per_file = {}
    for i in range(n_files):
        p = f"/data/r{rank}/f{i:03d}.bin"
        per_file[p] = FileRecord(p, {"POSIX_OPENS": 1,
                                     "POSIX_READS": reads_per_file,
                                     "POSIX_BYTES_READ": 65536},
                                 {"POSIX_F_READ_TIME": 0.01})
    rep = analyze(per_file, {}, elapsed_s=1.0, stat_sizes=False)
    rep.file_sizes = {p: 65536 for p in per_file}
    rep.segments = [Segment("POSIX", p, "read", 0, 65536,
                            0.1 * i, 0.1 * i + 0.05, 1)
                    for i, p in enumerate(sorted(per_file))]
    rep.findings = [Finding("small-file-storm", "Small-file storm", 0.5,
                            (0.0, 1.0), {"opens": float(n_files)}, "stage")]
    return rep


class _Rig:
    """One transport under test: how to build a per-rank transport and
    how to flush pending lines into the collector."""

    def __init__(self, name, collector, make, finalize, close, duplex):
        self.name = name
        self.collector = collector
        self.make = make            # rank -> Transport
        self.finalize = finalize    # () -> None (drain/stop servers)
        self.close = close
        self.duplex = duplex


@pytest.fixture(params=["loopback", "tcp", "spool"])
def rig(request, tmp_path):
    collector = FleetCollector(detectors=[])
    if request.param == "loopback":
        r = _Rig("loopback", collector,
                 make=lambda rank: LoopbackTransport(collector.ingest_line),
                 finalize=lambda: None, close=lambda: None, duplex=True)
    elif request.param == "tcp":
        server = CollectorServer(collector, idle_timeout_s=1.0)
        r = _Rig("tcp", collector,
                 make=lambda rank: TcpTransport("127.0.0.1", server.port),
                 finalize=lambda: None, close=server.close, duplex=True)
    else:
        spool = str(tmp_path / "spool")
        reader = SpoolReader(spool)      # persistent: drains incrementally
        r = _Rig("spool", collector,
                 make=lambda rank: SpoolTransport(spool,
                                                  name=f"rank{rank:05d}"),
                 finalize=lambda: collector.ingest_spool(reader),
                 close=lambda: None, duplex=False)
    yield r
    r.close()


def test_transport_conformance_ship_two_ranks(rig):
    """The same shipping sequence lands the same aggregate through
    every transport; duplex transports also recover a clock offset."""
    for rank in range(2):
        rep = RankReporter(rank, nprocs=2, runtime=DarshanRuntime(),
                           auto_attach=False)
        with rig.make(rank) as t:
            assert t.duplex is rig.duplex
            rep.ship(t, report=_synth_report(rank), handshake_rounds=3)
            if rig.duplex:
                assert rep.clock_offset_s is not None
            else:
                # spool: no reply channel, so the handshake runs against
                # the spool file's mtime and ships a wall offset instead
                assert rep.clock_offset_s is None
                assert rep.clock_wall_offset_s is not None
    rig.finalize()
    fleet = rig.collector.report()
    assert sorted(fleet.ranks) == [0, 1]
    assert fleet.nprocs == 2
    assert fleet.posix.reads == 2 * 4 * 3
    assert fleet.posix.bytes_read == 2 * 4 * 65536
    assert {f.detector for f in fleet.findings} == {"small-file-storm"}
    assert {f.rank for f in fleet.findings} == {0, 1}
    assert rig.collector.stats["reports"] == 2
    assert rig.collector.stats["hellos"] == 2
    assert rig.collector.stats["errors"] == 0
    # every rig measured an offset now — duplex via the reply-based
    # handshake, spool via the file-mtime wall offset pivoted through
    # the collector's wall anchor; unskewed same-host clocks land small
    for s in fleet.ranks.values():
        assert abs(s.clock_offset_s) < 2.0


def test_transport_conformance_register_verb_roundtrip(rig):
    """A register_verb-added message kind round-trips end to end
    through every transport without modifying repro.link internals
    (ISSUE 4 acceptance)."""
    def handler(endpoint, msg):
        coll = endpoint.context
        stash = getattr(coll, "custom_stash", None)
        if stash is None:
            stash = coll.custom_stash = []
        stash.append((msg.rank, msg.payload))
        return msg.reply("ok")

    register_verb("gpu-direct-stats", handler)
    try:
        for rank in range(2):
            with rig.make(rank) as t:
                reply = t(encode("gpu-direct-stats", rank,
                                 {"hits": 10 + rank}))
                if rig.duplex:
                    assert decode(reply).kind == "ok"
                else:
                    assert reply is None
        rig.finalize()
    finally:
        get_registry("verb").unregister("gpu-direct-stats")
    assert rig.collector.custom_stash == [(0, {"hits": 10}),
                                          (1, {"hits": 11})]
    assert rig.collector.stats["errors"] == 0


def test_transport_conformance_streamed_findings_superseded(rig):
    """Mid-run findings pushes surface immediately and the rank's final
    report supersedes them — no double counting, on any transport."""
    finding = Finding("checkpoint-stall", "Checkpoint stall", 0.9,
                      (0.0, 0.5), {"fsyncs": 4.0}, "async checkpoints")
    with rig.make(0) as t:
        t(payloads.encode_findings(0, [finding], streaming=True))
        rig.finalize()
        mid = rig.collector.report()
        assert [f.detector for f in mid.findings] == ["checkpoint-stall"]
        assert mid.findings[0].rank == 0          # provenance stamped
        # now the authoritative window report lands for the same rank
        rep = _synth_report(0)
        rep.findings = [finding]
        RankReporter(0, nprocs=1, runtime=DarshanRuntime(),
                     auto_attach=False).ship(t, report=rep,
                                             handshake_rounds=1)
    rig.finalize()
    final = rig.collector.report()
    assert [f.detector for f in final.findings] == ["checkpoint-stall"]
    assert len(final.findings) == 1               # superseded, not added


def test_spool_replay_tolerates_corrupt_lines(tmp_path):
    """One bad byte must not make the rest of a capture unreplayable:
    ingest_spool counts the error and keeps draining."""
    spool = str(tmp_path / "spool")
    t = SpoolTransport(spool, name="rank00000")
    t(encode("hello", 0, {"nprocs": 1}))
    t._f.write("{corrupt not json\n")          # torn/corrupt line
    t._f.flush()
    t(encode("bye", 0))
    t.close()
    coll = FleetCollector(detectors=[])
    assert coll.ingest_spool(spool) == 2       # both good lines landed
    assert coll.stats["errors"] == 1
    assert coll.stats["hellos"] == 1


def test_standalone_findings_push_survives_the_report():
    """Only streaming=True pushes are superseded by the rank's final
    report; a standalone push is authoritative and persists."""
    coll = FleetCollector(detectors=[])
    standalone = Finding("metadata-storm", "Metadata storm", 0.7,
                         (0.0, 1.0), {"stats": 9.0}, "cache sizes")
    coll.ingest_line(payloads.encode_findings(0, [standalone],
                                              streaming=False))
    coll.ingest_line(payloads.encode_report(0, _synth_report(0)))
    kinds = [f.detector for f in coll.report().findings]
    assert "metadata-storm" in kinds           # survived the report
    assert "small-file-storm" in kinds         # the report's own finding


def test_tcp_transport_reconnects_after_idle_reap_but_not_fresh():
    """A reused connection the server idle-reaped self-heals with one
    retry; a fresh connection's failure surfaces immediately."""
    coll = FleetCollector(detectors=[])
    server = CollectorServer(coll, idle_timeout_s=0.2)
    try:
        with TcpTransport("127.0.0.1", server.port) as t:
            assert t(encode("bye", 0)) == "ok"
            time.sleep(0.7)                    # server reaps the conn
            assert t(encode("bye", 0)) == "ok"   # transparent reconnect
    finally:
        server.close()
    # fresh connection against the now-closed port: raises, no retry loop
    with pytest.raises(OSError):
        TcpTransport("127.0.0.1", server.port)(encode("bye", 0))


def test_loopback_accepts_endpoint_and_callable():
    got = []
    ep = Endpoint(handlers={"bye": lambda e, m: "ok"})
    assert LoopbackTransport(ep)(encode("bye", 0)) == "ok"
    assert LoopbackTransport(lambda line: got.append(line))(
        encode("bye", 0)) is None
    assert len(got) == 1
    with pytest.raises(TypeError):
        LoopbackTransport(object())


def test_as_transport_wraps_callables():
    t = as_transport(lambda line: "ok")
    assert isinstance(t, CallableTransport) and t.duplex
    assert as_transport(t) is t
    with pytest.raises(TypeError):
        as_transport(42)


def test_spool_reader_tails_incrementally(tmp_path):
    spool = str(tmp_path / "spool")
    t = SpoolTransport(spool, name="rank00000")
    reader = SpoolReader(spool)
    t(encode("hello", 0, {"nprocs": 1}))
    first = reader.poll()
    assert len(first) == 1 and decode(first[0]).kind == "hello"
    assert reader.poll() == []                    # nothing new
    t(encode("bye", 0))
    t.close()
    second = reader.poll()
    assert [decode(x).kind for x in second] == ["bye"]
    # a fresh reader replays the finished spool from the top
    assert len(SpoolReader(spool).poll()) == 2


# --------------------------------------------- ProfileServer dual stack
def test_profile_server_speaks_typed_messages(tmp_path):
    paths = []
    for i in range(4):
        p = tmp_path / f"f{i}.bin"
        p.write_bytes(b"x" * 8192)
        paths.append(str(p))
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt, rank=3, nprocs=8)
    try:
        with TcpTransport("127.0.0.1", srv.port) as t:
            hello = t.request(Message("hello",
                                      payload={"link_v": LINK_VERSION}))
            assert hello.kind == "hello"
            assert hello.payload["link_v"] == LINK_VERSION
            assert hello.payload["nprocs"] == 8
            assert t.request(Message("status")).payload["active"] is False
            assert t.request(Message("start")).kind == "ok"
            for p in paths:
                fd = os.open(p, os.O_RDONLY)
                os.read(fd, 16384)
                os.close(fd)
            stop = t.request(Message("stop"))
            assert stop.kind == "ok" and stop.payload["reads"] >= 4
            clk = t.request(Message("clock", payload={"t_send": 1.5}))
            assert clk.kind == "clock_reply"
            assert clk.payload["echo"] == 1.5 and "t_coll" in clk.payload
            # typed report reply feeds a collector like any rank payload
            report_line = t(Message("report").encode())
            coll = FleetCollector(detectors=[])
            coll.ingest_line(report_line)
            assert coll.report().ranks[3].posix.bytes_read == 4 * 8192
            # and the legacy text protocol still works on the same port
            assert control(srv.port, "status") == "active=False"
    finally:
        srv.close()


def test_profile_server_typed_stop_without_start_is_error():
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt)
    try:
        with TcpTransport("127.0.0.1", srv.port) as t:
            err = t.request(Message("stop"))
            assert err.kind == "error"
            assert "not started" in err.payload["error"]
            bad = t.request(Message("bye"))      # no handler on this server
            assert bad.kind == "error"
    finally:
        srv.close()


def test_idle_timeout_is_plumbed(tmp_path):
    """A newline-less client's command is answered after the configured
    idle timeout — the old hardcoded 2.0 s is now a parameter."""
    rt = reset_runtime()
    srv = ProfileServer(runtime=rt, idle_timeout_s=0.3)
    try:
        assert srv._server.idle_timeout_s == 0.3
        with socket.create_connection(("127.0.0.1", srv.port)) as s:
            s.settimeout(5)
            t0 = time.monotonic()
            s.sendall(b"status")                 # no newline, kept open
            assert s.recv(4096) == b"active=False\n"
            assert time.monotonic() - t0 < 1.5   # ~0.3s idle, not 2s
    finally:
        srv.close()
    from repro.profiler import Profiler, ProfilerOptions
    prof = Profiler(ProfilerOptions(server_port=0, idle_timeout_s=0.7))
    srv = prof.serve()
    try:
        assert srv._server.idle_timeout_s == 0.7
    finally:
        srv.close()


def test_collector_server_close_joins_handlers():
    """CollectorServer.close() got the same handler-thread join
    hardening ProfileServer.close() has: back-to-back servers on one
    port are safe."""
    cs = CollectorServer()
    port = cs.port
    sock = socket.create_connection(("127.0.0.1", port), timeout=5)
    sock.sendall(encode("hello", 0, {"nprocs": 1}).encode() + b"\n")
    from repro.link import recv_reply
    assert decode(recv_reply(sock)).kind == "hello"
    cs.close()
    assert all(not t.is_alive() for t in cs._server._conn_threads)
    sock.close()
    cs2 = CollectorServer(port=port)
    try:
        assert cs2.port == port
    finally:
        cs2.close()


# ------------------------------------------------- spawned fleet (e2e)
def _fleet_files(root, nranks, per_rank, size):
    files = {}
    for r in range(nranks):
        d = os.path.join(str(root), f"r{r}")
        os.makedirs(d, exist_ok=True)
        files[r] = []
        for i in range(per_rank):
            p = os.path.join(d, f"{i:03d}.bin")
            with open(p, "wb") as f:
                f.write(b"x" * size)
            files[r].append(p)
    return files


@pytest.mark.parametrize("transport", ["tcp", "spool"])
def test_spawned_fleet_matches_simulated(tmp_path, transport):
    """ISSUE 4 acceptance: mode='fleet', launch='spawn' runs real OS
    processes and its Report matches a simulate_fleet run on the same
    workload — same global counters, same finding kinds."""
    from repro.profiler import Profiler, ProfilerOptions
    files = _fleet_files(tmp_path, 4, 6, 32768)

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=8192)

    sim = Profiler(ProfilerOptions(mode="fleet", nranks=4)).run(workload)
    spawned = Profiler(ProfilerOptions(
        mode="fleet", launch="spawn", fleet_ranks=4,
        transport=transport)).run(workload)
    assert spawned.mode == "fleet" and spawned.nprocs == 4
    assert sorted(spawned.ranks) == [0, 1, 2, 3]
    # real processes: every rank ran in its own pid, none in ours
    pids = {s.pid for s in spawned.fleet.ranks.values()}
    assert len(pids) == 4 and os.getpid() not in pids
    assert spawned.counters() == sim.counters()
    assert ({f.detector for f in spawned.findings}
            == {f.detector for f in sim.findings})
    if transport == "tcp":
        assert any(s.clock_offset_s != 0.0
                   for s in spawned.fleet.ranks.values())


def test_spawned_fleet_streams_insight_findings(tmp_path):
    """Child ranks push findings mid-run; the tiny-file storm shows up
    with rank provenance in the final report exactly like a simulated
    insight fleet."""
    from repro.profiler import Profiler, ProfilerOptions
    files = _fleet_files(tmp_path, 2, 48, 1024)

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p, chunk=4096)

    report = Profiler(ProfilerOptions(
        mode="fleet", launch="spawn", fleet_ranks=2, insight=True,
        insight_interval_s=0.1)).run(workload)
    storms = [f for f in report.findings
              if f.detector == "small-file-storm"]
    assert {f.rank for f in storms} == {0, 1}
    assert report.fleet.collector_stats["reports"] == 2


def test_spawned_fleet_rank_failure_raises(tmp_path):
    from repro.profiler import Profiler, ProfilerOptions

    def workload(rank, io):
        if rank == 1:
            raise RuntimeError("rank 1 dies")

    with pytest.raises(RuntimeError, match="fleet ranks failed"):
        Profiler(ProfilerOptions(mode="fleet", launch="spawn",
                                 fleet_ranks=2)).run(workload)


def test_thread_fleet_over_tcp_and_spool_transports(tmp_path):
    """The simulated (thread) harness rides the real wires too:
    transport='tcp'/'spool' with launch='thread'."""
    from repro.profiler import Profiler, ProfilerOptions
    files = _fleet_files(tmp_path, 2, 4, 16384)

    def workload(rank, io):
        for p in files[rank]:
            io.read_file(p)

    base = Profiler(ProfilerOptions(mode="fleet", nranks=2)).run(workload)
    for transport in ("tcp", "spool"):
        rep = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                       transport=transport)).run(workload)
        assert rep.counters() == base.counters()


def test_options_validate_link_fields():
    from repro.profiler import ProfilerOptions, ProfilerOptionsError
    with pytest.raises(ProfilerOptionsError, match="launch"):
        ProfilerOptions(mode="fleet", launch="mpi").validate()
    with pytest.raises(ProfilerOptionsError, match="loopback"):
        ProfilerOptions(mode="fleet", launch="spawn",
                        transport="loopback").validate()
    with pytest.raises(ProfilerOptionsError, match="spool_dir"):
        ProfilerOptions(mode="fleet", transport="tcp",
                        spool_dir="/tmp/x").validate()
    with pytest.raises(ProfilerOptionsError, match="idle_timeout_s"):
        ProfilerOptions(idle_timeout_s=0.0).validate()
    with pytest.raises(ProfilerOptionsError, match="fleet_ranks"):
        ProfilerOptions(mode="fleet", nranks=8, fleet_ranks=4)
    with pytest.raises(ProfilerOptionsError, match="fleet-mode"):
        ProfilerOptions(transport="tcp").validate()
    # fleet_ranks is a full alias: with_overrides keeps them in sync
    opts = ProfilerOptions(mode="fleet", fleet_ranks=4).validate()
    assert opts.nranks == 4
    assert opts.with_overrides(handshake_rounds=5).nranks == 4
    assert ProfilerOptions(mode="fleet", launch="spawn",
                           spool_dir="/tmp/x").resolved_transport() \
        == "spool"
