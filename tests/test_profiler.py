"""repro.profiler: façade, options, plugin registry, unified Report,
deprecation shims, and the ProfileServer lifecycle satellites.

The equivalence tests are the PR's acceptance bar: the façade must
produce the same counters/findings as the hand-wired legacy paths on
the same workload, in both local and fleet mode."""
import json
import os
import socket
import warnings

import pytest

from repro.core import ProfileServerError, ProfileSession, reset_runtime
from repro.core.session import ProfileServer, control
from repro.profiler import (BUILTIN_ADVISORS, BUILTIN_DETECTORS,
                            BUILTIN_EXPORTERS, BUILTIN_FLEET_DETECTORS,
                            Profiler, ProfilerOptions, ProfilerOptionsError,
                            RegistryError, Report, available, get_registry,
                            register_detector, register_exporter)


def make_tiny_files(root, n=64, size=2048):
    paths = []
    for i in range(n):
        p = os.path.join(str(root), f"tiny_{i:04d}.bin")
        with open(p, "wb") as f:
            f.write(b"x" * size)
        paths.append(p)
    return paths


def tiny_storm(paths):
    """Small-file storm with the EOF double-read pattern."""
    def run():
        for p in paths:
            fd = os.open(p, os.O_RDONLY)
            os.read(fd, 1 << 20)
            os.read(fd, 1 << 20)
            os.close(fd)
    return run


def fleet_workload(paths, nranks):
    def run(rank, io):
        for p in paths[rank::nranks]:
            io.read_file(p, chunk=16384)
    return run


# ---------------------------------------------------------------- registry
def test_builtin_plugins_discoverable_by_name():
    assert set(BUILTIN_DETECTORS) <= set(available("detector"))
    assert set(BUILTIN_FLEET_DETECTORS) <= set(available("fleet_detector"))
    assert set(BUILTIN_EXPORTERS) <= set(available("exporter"))
    assert set(BUILTIN_ADVISORS) <= set(available("advisor"))


def test_register_create_unregister_roundtrip():
    calls = []

    def factory(options):
        calls.append(options)
        return "plugin-instance"

    register_detector("test-roundtrip", factory)
    try:
        assert "test-roundtrip" in available("detector")
        reg = get_registry("detector")
        assert reg.create("test-roundtrip", "opts") == "plugin-instance"
        assert calls == ["opts"]
    finally:
        get_registry("detector").unregister("test-roundtrip")
    assert "test-roundtrip" not in available("detector")


def test_register_decorator_form():
    @register_detector("test-decorated")
    def make(options):
        return "made"

    try:
        assert get_registry("detector").create("test-decorated") == "made"
    finally:
        get_registry("detector").unregister("test-decorated")


def test_duplicate_registration_needs_override():
    register_exporter("test-dup", lambda opts: lambda rep, path=None: 1)
    try:
        with pytest.raises(RegistryError, match="already registered"):
            register_exporter("test-dup",
                             lambda opts: lambda rep, path=None: 2)
        register_exporter("test-dup",
                          lambda opts: lambda rep, path=None: 2,
                          override=True)
        fn = get_registry("exporter").create("test-dup")
        assert fn(None) == 2
    finally:
        get_registry("exporter").unregister("test-dup")


def test_unknown_name_error_lists_available():
    with pytest.raises(RegistryError, match="unknown detector.*available"):
        get_registry("detector").create("no-such-detector")
    with pytest.raises(RegistryError, match="unknown plugin kind"):
        get_registry("no-such-kind")


def test_profiler_rejects_unknown_plugin_names_at_construction():
    with pytest.raises(RegistryError, match="no-such-exporter"):
        Profiler(ProfilerOptions(exporters=("no-such-exporter",)))
    with pytest.raises(RegistryError, match="no-such-detector"):
        Profiler(ProfilerOptions(insight=True,
                                 detectors=("no-such-detector",)))
    with pytest.raises(RegistryError, match="no-such-advisor"):
        Profiler(ProfilerOptions(advisors=("no-such-advisor",)))


# ----------------------------------------------------------------- options
@pytest.mark.parametrize("kwargs,match", [
    (dict(mode="cluster"), "mode"),
    (dict(detectors=("small-file-storm",)), "insight is off"),
    (dict(exporters="chrome_trace"), "bare string"),
    (dict(exporters=("chrome_trace", "")), "non-empty"),
    (dict(insight_interval_s=0.0), "insight_interval_s"),
    (dict(step_window=(5, 2)), "step_window"),
    (dict(step_window=(-1, 2)), "step_window"),
    (dict(step_every=0), "step_every"),
    (dict(server_port=70000), "server_port"),
    (dict(mode="fleet", nranks=0), "nranks"),
    (dict(mode="fleet", nranks=4, clock_skew_s=(0.0,)), "clock_skew_s"),
    (dict(mode="fleet", nranks=2, handshake_rounds=0), "handshake_rounds"),
    (dict(mode="fleet", nranks=2, step_window=(0, 1)), "local-mode"),
    (dict(clock_skew_s=(0.0,)), "fleet-mode"),
    (dict(nranks=4), "fleet"),
])
def test_options_validation_rejects(kwargs, match):
    with pytest.raises(ProfilerOptionsError, match=match):
        ProfilerOptions(**kwargs).validate()


def test_options_with_overrides_validates():
    opts = ProfilerOptions().with_overrides(insight=True,
                                            detectors=("metadata-storm",))
    assert opts.detectors == ("metadata-storm",)
    with pytest.raises(ProfilerOptionsError):
        opts.with_overrides(mode="bogus")


# ------------------------------------------------------- local equivalence
def test_local_facade_matches_legacy_session(tmp_path):
    paths = make_tiny_files(tmp_path)
    workload = tiny_storm(paths)

    rt = reset_runtime()
    legacy_sess = ProfileSession(rt, insight=True, insight_interval_s=60.0)
    with legacy_sess:
        workload()
    legacy = legacy_sess.reports[0]

    rt = reset_runtime()
    prof = Profiler(ProfilerOptions(mode="local", insight=True,
                                    insight_interval_s=60.0), runtime=rt)
    report = prof.run(workload)

    assert isinstance(report, Report)
    assert report.mode == "local"
    p, q = report.posix, legacy.posix
    assert (p.opens, p.reads, p.bytes_read, p.zero_reads) \
        == (q.opens, q.reads, q.bytes_read, q.zero_reads)
    assert sorted(f.detector for f in report.findings) \
        == sorted(f.detector for f in legacy.findings)
    assert report.per_file.keys() == legacy.per_file.keys()


def test_detector_selection_limits_findings(tmp_path):
    paths = make_tiny_files(tmp_path)
    rt = reset_runtime()
    prof = Profiler(ProfilerOptions(insight=True,
                                    detectors=("metadata-storm",),
                                    insight_interval_s=60.0), runtime=rt)
    report = prof.run(tiny_storm(paths))
    # the workload is a textbook small-file storm, but that detector was
    # not selected — nothing may fire
    assert all(f.detector == "metadata-storm" for f in report.findings)
    assert not any(f.detector == "small-file-storm"
                   for f in report.findings)


def test_context_manager_and_manual_windows(tmp_path):
    paths = make_tiny_files(tmp_path, n=8)
    rt = reset_runtime()
    prof = Profiler(runtime=rt)
    with prof:
        tiny_storm(paths)()
    assert prof.report is not None
    assert prof.report.counters()["opens"] == 8
    prof.start()
    tiny_storm(paths)()
    rep2 = prof.stop()
    assert len(prof.reports) == 2
    assert rep2.counters()["opens"] == 8


def test_advisors_run_and_land_on_report(tmp_path):
    paths = make_tiny_files(tmp_path)
    rt = reset_runtime()
    prof = Profiler(ProfilerOptions(
        insight=True, insight_interval_s=60.0,
        advisors=("staging", "workload-character")), runtime=rt)
    report = prof.run(tiny_storm(paths))
    assert report.advice["workload-character"] == "small-file"
    assert report.advice["staging"].total_files > 0
    assert "staging" in report.summary()


def test_custom_exporter_via_report_export(tmp_path):
    register_exporter("test-counters",
                      lambda opts: lambda rep, path=None: rep.counters())
    try:
        paths = make_tiny_files(tmp_path, n=4)
        rt = reset_runtime()
        report = Profiler(runtime=rt).run(tiny_storm(paths))
        assert report.export("test-counters")["opens"] == 4
    finally:
        get_registry("exporter").unregister("test-counters")


def test_export_all_writes_selected_exporters(tmp_path):
    paths = make_tiny_files(tmp_path, n=4)
    rt = reset_runtime()
    report = Profiler(runtime=rt).run(tiny_storm(paths))
    out = report.export_all(str(tmp_path / "exports"))
    assert set(out) == {"chrome_trace", "json_report", "darshan_log"}
    for path in out.values():
        assert os.path.getsize(path) > 0
    with open(out["json_report"]) as f:
        assert json.load(f)["posix"]["opens"] == 4


def test_step_callback_through_facade(tmp_path):
    paths = make_tiny_files(tmp_path, n=12)
    rt = reset_runtime()
    prof = Profiler(ProfilerOptions(step_window=(2, 5)), runtime=rt)
    cb = prof.step_callback()
    for step in range(8):
        cb.on_step_begin(step)
        if 2 <= step <= 5:
            tiny_storm(paths[step:step + 1])()
        cb.on_step_end(step)
    assert len(prof.reports) == 1
    assert prof.report.counters()["opens"] == 4


# ------------------------------------------------------- fleet equivalence
def test_fleet_facade_matches_legacy_run_simulated_fleet(tmp_path):
    paths = make_tiny_files(tmp_path, n=32, size=16384)
    nranks = 4
    workload = fleet_workload(paths, nranks)

    from repro.fleet import run_simulated_fleet
    reset_runtime()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_simulated_fleet(nranks, workload)

    reset_runtime()
    prof = Profiler(ProfilerOptions(mode="fleet", nranks=nranks))
    report = prof.run(workload)

    assert report.mode == "fleet"
    assert report.nprocs == legacy.nprocs == nranks
    assert report.counters()["reads"] == legacy.posix.reads
    assert report.counters()["bytes_read"] == legacy.posix.bytes_read
    assert sorted(f.detector for f in report.findings) \
        == sorted(f.detector for f in legacy.findings)
    assert sorted(report.ranks) == sorted(legacy.ranks)
    # merged per-file view sums the ranks
    assert sum(rec.get("POSIX_READS") for rec in report.per_file.values()) \
        == report.counters()["reads"]


def test_fleet_per_file_timestamps_are_clock_aligned(tmp_path):
    # skewed rank clocks: merged per-file timestamps must land on the
    # fleet timeline (like segments), not mix raw rank timebases
    paths = make_tiny_files(tmp_path, n=8, size=4096)
    skew = 50.0
    reset_runtime()
    prof = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                    clock_skew_s=(0.0, skew)))
    report = prof.run(fleet_workload(paths, 2))
    seg_t1 = max(s.end for s in report.segments)
    for rec in report.per_file.values():
        for k, v in rec.fcounters.items():
            if k.endswith("_TIMESTAMP"):
                assert v <= seg_t1 + 1.0, \
                    f"{rec.path} {k}={v} is on a skewed rank clock"


def test_fleet_detectors_conflict_with_explicit_collector(tmp_path):
    from repro.fleet import FleetCollector
    paths = make_tiny_files(tmp_path, n=4)
    reset_runtime()
    prof = Profiler(ProfilerOptions(mode="fleet", nranks=2,
                                    fleet_detectors=("load-imbalance",)))
    with pytest.raises(RuntimeError, match="not both"):
        prof.run(fleet_workload(paths, 2), collector=FleetCollector())


def test_run_simulated_fleet_shim_keeps_engine_instances(tmp_path):
    # legacy callers could pass an InsightEngine object; the shim must
    # not collapse it to bool
    from repro.fleet import run_simulated_fleet
    from repro.insight import InsightEngine
    from repro.insight.detectors import MetadataStormDetector
    paths = make_tiny_files(tmp_path, n=4)
    engine = InsightEngine(detectors=[MetadataStormDetector()])
    reset_runtime()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fleet = run_simulated_fleet(2, fleet_workload(paths, 2),
                                    insight=engine)
    assert len([x for x in w
                if issubclass(x.category, DeprecationWarning)]) == 1
    assert fleet.nprocs == 2


def test_serve_uses_fresh_engine_per_server():
    reset_runtime()
    prof = Profiler(ProfilerOptions(insight=True, insight_interval_s=60.0))
    srv = prof.serve()
    try:
        assert srv.session.insight_engine is not None
        assert srv.session.insight_engine is not prof.insight_engine
    finally:
        srv.close()


def test_fleet_detector_selection(tmp_path):
    paths = make_tiny_files(tmp_path, n=16, size=16384)

    def skewed(rank, io):
        # rank 0 reads everything => load imbalance
        for p in (paths if rank == 0 else paths[:1]):
            io.read_file(p)

    reset_runtime()
    prof = Profiler(ProfilerOptions(mode="fleet", nranks=3,
                                    fleet_detectors=("load-imbalance",)))
    report = prof.run(skewed)
    assert all(f.detector == "load-imbalance" for f in report.findings)


def test_fleet_export_all(tmp_path):
    paths = make_tiny_files(tmp_path, n=8, size=4096)
    reset_runtime()
    prof = Profiler(ProfilerOptions(mode="fleet", nranks=2))
    report = prof.run(fleet_workload(paths, 2))
    out = report.export_all(str(tmp_path / "fleet_exports"))
    with open(out["chrome_trace"]) as f:
        trace = json.load(f)
    pids = {e["pid"] for e in trace["traceEvents"]}
    assert {"rank 0", "rank 1"} <= pids


# ------------------------------------------------------- deprecation shims
def test_run_simulated_fleet_shim_warns_once_and_matches(tmp_path):
    paths = make_tiny_files(tmp_path, n=16, size=8192)
    workload = fleet_workload(paths, 2)
    from repro.fleet import run_simulated_fleet
    reset_runtime()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = run_simulated_fleet(2, workload)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "repro.profiler" in str(deps[0].message)

    reset_runtime()
    report = Profiler(ProfilerOptions(mode="fleet", nranks=2)).run(workload)
    assert legacy.posix.reads == report.counters()["reads"]
    assert legacy.posix.bytes_read == report.counters()["bytes_read"]


def test_pipeline_with_insight_shim_warns_once():
    from repro.data.pipeline import Pipeline
    from repro.insight import InsightEngine
    engine = InsightEngine()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p = Pipeline([1, 2, 3]).with_insight(engine)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1
    assert p.spec.insight_engine is engine
    # the replacement wires the same spec field without warning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        q = Pipeline([1, 2, 3]).with_profiler(engine)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert q.spec.insight_engine is engine


def test_pipeline_with_profiler_takes_facade():
    from repro.data.pipeline import Pipeline
    prof = Profiler(ProfilerOptions(insight=True))
    p = Pipeline([1]).with_profiler(prof)
    assert p.spec.insight_engine is prof.insight_engine
    with pytest.raises(ValueError, match="insight"):
        Pipeline([1]).with_profiler(Profiler())


def test_core_insight_reexport_shim_warns_once():
    import repro.core as core
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        engine_cls = core.InsightEngine
        finding_cls = core.Finding
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 2            # one per deprecated attribute access
    from repro.insight import Finding, InsightEngine
    assert engine_cls is InsightEngine
    assert finding_cls is Finding


def test_trainer_legacy_config_routes_through_facade():
    # no jax step needed: just verify the wiring objects
    from repro.profiler import Profiler as P
    import repro.train.trainer as trainer_mod
    tcfg = trainer_mod.TrainerConfig(profile_first=2, profile_last=5)
    t = trainer_mod.Trainer.__new__(trainer_mod.Trainer)
    t.tcfg = tcfg
    facade = t._make_facade(None)
    assert isinstance(facade, P)
    assert facade.options.step_window == (2, 5)
    cb = facade.step_callback()
    assert (cb.first, cb.last) == (2, 5)
    # explicit options object
    facade2 = t._make_facade(ProfilerOptions(step_window=(0, 3)))
    assert facade2.options.step_window == (0, 3)
    with pytest.raises(ValueError, match="step_window"):
        t._make_facade(ProfilerOptions())


# ----------------------------------------------- ProfileServer satellites
def test_profile_server_close_joins_handlers_and_frees_port(tmp_path):
    reset_runtime()
    srv = ProfileServer()
    port = srv.port
    # open a persistent pipelined connection so a handler thread is live
    sock = socket.create_connection(("127.0.0.1", port), timeout=5)
    sock.sendall(b"status\n")
    from repro.core.session import recv_reply
    assert recv_reply(sock).startswith("active=")
    srv.close()
    # the close-join hardening lives in the shared repro.link LineServer
    assert all(not t.is_alive() for t in srv._server._conn_threads)
    sock.close()
    # back-to-back server on the SAME port must bind cleanly
    srv2 = ProfileServer(port=port)
    try:
        assert srv2.port == port
        assert control(port, "status") == "active=False"
    finally:
        srv2.close()


def test_control_parse_raises_profile_server_error():
    reset_runtime()
    srv = ProfileServer()
    try:
        # 'stop' with no active session => error reply
        with pytest.raises(ProfileServerError, match="stop"):
            control(srv.port, "stop", parse=True)
        # unknown verb => 'unknown' reply
        with pytest.raises(ProfileServerError, match="bogus"):
            control(srv.port, "bogus", parse=True)
        # well-formed non-JSON reply => malformed
        with pytest.raises(ProfileServerError, match="malformed"):
            control(srv.port, "start", parse=True)
        # raw mode is untouched
        assert control(srv.port, "status") == "active=True"
    finally:
        srv.close()


def test_facade_serve_starts_profile_server(tmp_path):
    paths = make_tiny_files(tmp_path, n=4)
    reset_runtime()
    prof = Profiler(ProfilerOptions(insight=True, insight_interval_s=60.0))
    srv = prof.serve()
    try:
        assert control(srv.port, "start") == "ok"
        tiny_storm(paths)()
        out = control(srv.port, "stop", parse=True)
        assert out["reads"] == 8
    finally:
        srv.close()
